//! One LIF neuron core (paper Fig. 1): accumulator register, saturating
//! adder, shift-based decay unit, threshold comparator, spike-count
//! register and enable gating.
//!
//! The core is modelled two-phase: the controller presents a [`NeuronCtrl`]
//! command word (the decoded control signals for this clock) and `tick`
//! commits the posedge. All datapath activity is recorded into
//! [`ActivityCounters`].
//!
//! Two representations share the same semantics:
//!
//! * [`LifNeuronCore`] — one neuron as an object; the readable reference
//!   model, kept for unit tests and documentation.
//! * [`LifNeuronArray`] — one whole layer as a structure-of-arrays (flat
//!   `acc` / `spike_count` buffers plus a multi-word enable bitmask, so
//!   hidden layers wider than 64 neurons fit). This is what
//!   [`crate::rtl::RtlCore`] actually runs — one array per layer of the
//!   topology: the per-cycle inner loops walk contiguous memory and skip
//!   disabled neurons by bit iteration instead of dispatching through an
//!   object array. The two are proven activity- and state-equivalent by
//!   the property test below.

use crate::config::SnnConfig;
use crate::fixed::leak;

use super::power::ActivityCounters;

/// Decoded per-clock control signals driven by the layer controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NeuronCtrl {
    /// Hold: no enable asserted this clock.
    Idle,
    /// `add_en`: integrate `weight` into the accumulator.
    Add { weight: i32 },
    /// `leak_en`: apply the shift-subtract decay.
    Leak,
    /// `fire_en`: evaluate the threshold comparator; fire & hard-reset when
    /// `acc ≥ V_th`.
    FireCheck,
    /// Synchronous reset (new inference window).
    Reset,
}

/// Architectural state of a single neuron core.
#[derive(Debug, Clone)]
pub struct LifNeuronCore {
    /// Membrane accumulator register (sign-extended to i32; physically
    /// `acc_bits` wide).
    acc: i32,
    /// Output spike count register (used by readout and pruning).
    spike_count: u32,
    /// Enable latch: cleared by the controller's pruning mask.
    enabled: bool,
    /// Fired-this-cycle flag (the `Fire` output wire).
    fired: bool,
    cfg_acc_bits: u32,
    cfg_decay_shift: u32,
    cfg_v_th: i32,
    cfg_v_rest: i32,
}

impl LifNeuronCore {
    pub fn new(cfg: &SnnConfig) -> Self {
        LifNeuronCore {
            acc: cfg.v_rest,
            spike_count: 0,
            enabled: true,
            fired: false,
            cfg_acc_bits: cfg.acc_bits,
            cfg_decay_shift: cfg.decay_shift,
            cfg_v_th: cfg.v_th,
            cfg_v_rest: cfg.v_rest,
        }
    }

    /// Membrane potential (the accumulator register).
    pub fn acc(&self) -> i32 {
        self.acc
    }

    /// Spike-count register.
    pub fn spike_count(&self) -> u32 {
        self.spike_count
    }

    /// Enable latch value.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The `Fire` wire: did the neuron fire on the last `tick`?
    pub fn fired(&self) -> bool {
        self.fired
    }

    /// Controller drives the enable latch (pruning mask).
    pub fn set_enabled(&mut self, en: bool) {
        self.enabled = en;
    }

    /// Commit one clock edge under `ctrl`. Returns the `Fire` wire value.
    pub fn tick(&mut self, ctrl: NeuronCtrl, act: &mut ActivityCounters) -> bool {
        self.fired = false;
        if !self.enabled && !matches!(ctrl, NeuronCtrl::Reset) {
            // Gated clock: a disabled neuron burns no dynamic power.
            return false;
        }
        match ctrl {
            NeuronCtrl::Idle => {}
            NeuronCtrl::Add { weight } => {
                let max = (1i32 << (self.cfg_acc_bits - 1)) - 1;
                let sum = i64::from(self.acc) + i64::from(weight);
                let clamped = sum.clamp(-(max as i64), max as i64) as i32;
                if clamped as i64 != sum {
                    act.saturations += 1;
                }
                act.adds += 1;
                self.write_acc(clamped, act);
            }
            NeuronCtrl::Leak => {
                let next = leak(self.acc, self.cfg_decay_shift);
                act.shifts += 1;
                act.adds += 1; // the subtract half of shift-subtract
                self.write_acc(next, act);
            }
            NeuronCtrl::FireCheck => {
                act.compares += 1;
                if self.acc >= self.cfg_v_th {
                    self.fired = true;
                    self.spike_count += 1;
                    act.reg_toggles += 1; // spike-count increment (approx.)
                    self.write_acc(self.cfg_v_rest, act);
                }
            }
            NeuronCtrl::Reset => {
                self.write_acc(self.cfg_v_rest, act);
                self.spike_count = 0;
                self.enabled = true;
                self.fired = false;
            }
        }
        self.fired
    }

    /// Combinational threshold check used in `FireMode::Immediate` during
    /// integration (comparator output without a clock commit).
    pub fn above_threshold(&self) -> bool {
        self.acc >= self.cfg_v_th
    }

    #[inline]
    fn write_acc(&mut self, next: i32, act: &mut ActivityCounters) {
        act.reg_toggles += u64::from(((self.acc as u32) ^ (next as u32)).count_ones());
        self.acc = next;
    }
}

// ---------------------------------------------------------------------------

/// One whole layer as a structure-of-arrays.
///
/// State layout: flat `acc` / `spike_count` vectors plus a multi-word
/// enable bitmask (bit `j % 64` of word `j / 64` = neuron `j` enabled), so
/// any layer width works — the paper's output layer has 10 neurons, the
/// MLP-shaped hidden layer 128.
///
/// Every mutator records exactly the [`ActivityCounters`] events the
/// per-neuron [`LifNeuronCore::tick`] would: adds, per-add saturations,
/// shift-subtract leaks, comparator evaluations and the Hamming distance of
/// every register write. Bit-exactness against a `Vec<LifNeuronCore>` is
/// pinned by `array_matches_core_reference` below.
#[derive(Debug, Clone)]
pub struct LifNeuronArray {
    acc: Vec<i32>,
    spike_count: Vec<u32>,
    /// Enable latch words; cleared by the pruning mask.
    enabled: Vec<u64>,
    acc_max: i32,
    decay_shift: u32,
    v_th: i32,
    v_rest: i32,
}

impl LifNeuronArray {
    /// Build an array sized to the config's *output* width — callers
    /// construct one per layer via [`crate::SnnConfig::layer_config`].
    pub fn new(cfg: &SnnConfig) -> Self {
        let n = cfg.n_outputs();
        LifNeuronArray {
            acc: vec![cfg.v_rest; n],
            spike_count: vec![0; n],
            enabled: Self::full_mask(n),
            acc_max: cfg.acc_max(),
            decay_shift: cfg.decay_shift,
            v_th: cfg.v_th,
            v_rest: cfg.v_rest,
        }
    }

    fn full_mask(n: usize) -> Vec<u64> {
        let words = n.div_ceil(64).max(1);
        let mut mask = vec![u64::MAX; words];
        let rem = n % 64;
        if rem != 0 {
            mask[words - 1] = (1u64 << rem) - 1;
        }
        if n == 0 {
            mask[0] = 0;
        }
        mask
    }

    /// Number of neurons.
    pub fn len(&self) -> usize {
        self.acc.len()
    }

    /// True when the layer has no neurons (never for valid configs).
    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }

    /// Membrane potential of neuron `j`.
    pub fn acc(&self, j: usize) -> i32 {
        self.acc[j]
    }

    /// All membrane potentials (borrowed; no allocation).
    pub fn accs(&self) -> &[i32] {
        &self.acc
    }

    /// All membrane potentials (owned copy).
    pub fn membranes(&self) -> Vec<i32> {
        self.acc.clone()
    }

    /// All spike-count registers.
    pub fn spike_counts(&self) -> &[u32] {
        &self.spike_count
    }

    /// Enable latch of neuron `j`.
    pub fn enabled(&self, j: usize) -> bool {
        (self.enabled[j / 64] >> (j % 64)) & 1 == 1
    }

    /// True while at least one neuron is still enabled.
    pub fn any_enabled(&self) -> bool {
        self.enabled.iter().any(|&w| w != 0)
    }

    /// Drive the enable latches from the controller's pruning mask.
    pub fn set_enables(&mut self, enables: &[bool]) {
        debug_assert_eq!(enables.len(), self.acc.len());
        self.enabled.iter_mut().for_each(|w| *w = 0);
        for (j, &e) in enables.iter().enumerate() {
            self.enabled[j / 64] |= u64::from(e) << (j % 64);
        }
    }

    #[inline(always)]
    fn write_acc(&mut self, j: usize, next: i32, act: &mut ActivityCounters) {
        act.reg_toggles += u64::from(((self.acc[j] as u32) ^ (next as u32)).count_ones());
        self.acc[j] = next;
    }

    /// Synchronous reset of every neuron (new inference window); re-enables
    /// the whole array, like `NeuronCtrl::Reset` on each core.
    pub fn reset(&mut self, act: &mut ActivityCounters) {
        for j in 0..self.acc.len() {
            self.write_acc(j, self.v_rest, act);
        }
        self.spike_count.fill(0);
        self.enabled = Self::full_mask(self.acc.len());
    }

    /// One BRAM row pulse: integrate `row[j]` into every *enabled* neuron
    /// with per-add saturation (ascending `j`, like the adder-tree fanout).
    #[inline]
    pub fn add_row(&mut self, row: &[i32], act: &mut ActivityCounters) {
        debug_assert_eq!(row.len(), self.acc.len());
        for wi in 0..self.enabled.len() {
            let mut m = self.enabled[wi];
            while m != 0 {
                let j = wi * 64 + m.trailing_zeros() as usize;
                m &= m - 1;
                let sum = i64::from(self.acc[j]) + i64::from(row[j]);
                let clamped = sum.clamp(-i64::from(self.acc_max), i64::from(self.acc_max)) as i32;
                if i64::from(clamped) != sum {
                    act.saturations += 1;
                }
                act.adds += 1;
                self.write_acc(j, clamped, act);
            }
        }
    }

    /// One `Leak` clock: shift-subtract decay on every enabled neuron.
    #[inline]
    pub fn leak_enabled(&mut self, act: &mut ActivityCounters) {
        for wi in 0..self.enabled.len() {
            let mut m = self.enabled[wi];
            while m != 0 {
                let j = wi * 64 + m.trailing_zeros() as usize;
                m &= m - 1;
                let next = leak(self.acc[j], self.decay_shift);
                act.shifts += 1;
                act.adds += 1; // the subtract half of shift-subtract
                self.write_acc(j, next, act);
            }
        }
    }

    /// One `Fire` clock (`FireMode::EndOfStep`): evaluate the threshold
    /// comparator of every enabled neuron, setting `fired[j]` and
    /// hard-resetting on a crossing. `fired` must be pre-cleared.
    pub fn fire_check(&mut self, fired: &mut [bool], act: &mut ActivityCounters) {
        debug_assert_eq!(fired.len(), self.acc.len());
        for wi in 0..self.enabled.len() {
            let mut m = self.enabled[wi];
            while m != 0 {
                let j = wi * 64 + m.trailing_zeros() as usize;
                m &= m - 1;
                act.compares += 1;
                if self.acc[j] >= self.v_th {
                    fired[j] = true;
                    self.spike_count[j] += 1;
                    act.reg_toggles += 1; // spike-count increment (approx.)
                    self.write_acc(j, self.v_rest, act);
                }
            }
        }
    }

    /// Mid-integration combinational fire (`FireMode::Immediate`): only
    /// neurons whose accumulator is at/above threshold commit a `FireCheck`
    /// (and its comparator activity), exactly like the cycle path's
    /// `above_threshold()` pre-gate. Returns true when any neuron fired.
    /// `fired` must be pre-cleared.
    pub fn immediate_fire(&mut self, fired: &mut [bool], act: &mut ActivityCounters) -> bool {
        debug_assert_eq!(fired.len(), self.acc.len());
        let mut any = false;
        for wi in 0..self.enabled.len() {
            let mut m = self.enabled[wi];
            while m != 0 {
                let j = wi * 64 + m.trailing_zeros() as usize;
                m &= m - 1;
                if self.acc[j] >= self.v_th {
                    act.compares += 1;
                    fired[j] = true;
                    any = true;
                    self.spike_count[j] += 1;
                    act.reg_toggles += 1;
                    self.write_acc(j, self.v_rest, act);
                }
            }
        }
        any
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SnnConfig {
        SnnConfig { v_th: 10, decay_shift: 1, acc_bits: 16, ..SnnConfig::paper() }
    }

    #[test]
    fn add_leak_fire_sequence() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 7 }, &mut act);
        assert_eq!(n.acc(), 7);
        n.tick(NeuronCtrl::Leak, &mut act);
        assert_eq!(n.acc(), 4); // 7 - (7>>1)=3
        n.tick(NeuronCtrl::Add { weight: 7 }, &mut act);
        assert_eq!(n.acc(), 11);
        let fired = n.tick(NeuronCtrl::FireCheck, &mut act);
        assert!(fired);
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 1);
    }

    #[test]
    fn disabled_neuron_is_inert_and_free() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.set_enabled(false);
        let before = act;
        n.tick(NeuronCtrl::Add { weight: 100 }, &mut act);
        n.tick(NeuronCtrl::Leak, &mut act);
        n.tick(NeuronCtrl::FireCheck, &mut act);
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 0);
        assert_eq!(act, before, "disabled neuron must record zero activity");
    }

    #[test]
    fn reset_reenables() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 25 }, &mut act);
        n.tick(NeuronCtrl::FireCheck, &mut act);
        n.set_enabled(false);
        n.tick(NeuronCtrl::Reset, &mut act);
        assert!(n.enabled());
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 0);
    }

    #[test]
    fn saturation_is_counted() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&SnnConfig { acc_bits: 8, v_th: 100, ..cfg() });
        for _ in 0..3 {
            n.tick(NeuronCtrl::Add { weight: 120 }, &mut act);
        }
        // 120, then 240 -> clamp 127, then 127+120 -> clamp.
        assert_eq!(n.acc(), 127);
        assert_eq!(act.saturations, 2);
    }

    #[test]
    fn negative_membrane_decays_up() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: -9 }, &mut act);
        assert_eq!(n.acc(), -9);
        n.tick(NeuronCtrl::Leak, &mut act);
        // -9 - (-9>>1) = -9 - (-5) = -4
        assert_eq!(n.acc(), -4);
    }

    #[test]
    fn toggle_counting_tracks_hamming_distance() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 0b1111 }, &mut act);
        assert_eq!(act.reg_toggles, 4); // 0 -> 0b1111 toggles 4 bits
    }

    /// The SoA array and a `Vec<LifNeuronCore>` must stay state- and
    /// activity-identical under random command streams — the foundation of
    /// the RTL core's fast path.
    #[test]
    fn array_matches_core_reference() {
        use crate::testutil::PropRunner;

        PropRunner::new("lif_array_equiv", 60).run(|g| {
            // Mostly narrow arrays, sometimes wider than one mask word so
            // the multi-word enable iteration is exercised too.
            let n = if g.rng.below(4) == 0 {
                g.rng.range_i32(65, 140) as usize
            } else {
                g.rng.range_i32(1, 12) as usize
            };
            let cfg = SnnConfig {
                topology: vec![784, n],
                v_th: g.rng.range_i32(5, 60),
                decay_shift: g.rng.range_i32(1, 4) as u32,
                // Narrow accumulator so per-add saturation gets exercised.
                acc_bits: g.rng.range_i32(8, 16) as u32,
                ..SnnConfig::paper()
            };
            let mut array = LifNeuronArray::new(&cfg);
            let mut cores: Vec<LifNeuronCore> =
                (0..n).map(|_| LifNeuronCore::new(&cfg)).collect();
            let mut act_a = ActivityCounters::default();
            let mut act_c = ActivityCounters::default();
            let mut fired_a = vec![false; n];

            for _ in 0..120 {
                match g.rng.below(6) {
                    0 => {
                        let row = g.vec_i32(n, -120, 120);
                        array.add_row(&row, &mut act_a);
                        for (j, c) in cores.iter_mut().enumerate() {
                            c.tick(NeuronCtrl::Add { weight: row[j] }, &mut act_c);
                        }
                    }
                    1 => {
                        array.leak_enabled(&mut act_a);
                        for c in cores.iter_mut() {
                            c.tick(NeuronCtrl::Leak, &mut act_c);
                        }
                    }
                    2 => {
                        fired_a.fill(false);
                        array.fire_check(&mut fired_a, &mut act_a);
                        for (j, c) in cores.iter_mut().enumerate() {
                            let f = c.tick(NeuronCtrl::FireCheck, &mut act_c);
                            assert_eq!(fired_a[j], f, "fire wire diverges at {j}");
                        }
                    }
                    3 => {
                        fired_a.fill(false);
                        array.immediate_fire(&mut fired_a, &mut act_a);
                        for (j, c) in cores.iter_mut().enumerate() {
                            let mut f = false;
                            if c.enabled() && c.above_threshold() {
                                f = c.tick(NeuronCtrl::FireCheck, &mut act_c);
                            }
                            assert_eq!(fired_a[j], f, "immediate fire diverges at {j}");
                        }
                    }
                    4 => {
                        let enables: Vec<bool> =
                            (0..n).map(|_| g.rng.next_u32() & 1 == 1).collect();
                        array.set_enables(&enables);
                        for (c, &e) in cores.iter_mut().zip(&enables) {
                            c.set_enabled(e);
                        }
                    }
                    _ => {
                        array.reset(&mut act_a);
                        for c in cores.iter_mut() {
                            c.tick(NeuronCtrl::Reset, &mut act_c);
                        }
                    }
                }
                for (j, c) in cores.iter().enumerate() {
                    assert_eq!(array.acc(j), c.acc(), "membrane diverges at {j}");
                    assert_eq!(array.spike_counts()[j], c.spike_count(), "count at {j}");
                    assert_eq!(array.enabled(j), c.enabled(), "enable at {j}");
                }
                assert_eq!(act_a, act_c, "activity counters diverge");
            }
        });
    }
}
