//! One LIF neuron core (paper Fig. 1): accumulator register, saturating
//! adder, shift-based decay unit, threshold comparator, spike-count
//! register and enable gating.
//!
//! The core is modelled two-phase: the controller presents a [`NeuronCtrl`]
//! command word (the decoded control signals for this clock) and `tick`
//! commits the posedge. All datapath activity is recorded into
//! [`ActivityCounters`].
//!
//! Three representations share the same semantics:
//!
//! * [`LifNeuronCore`] — one neuron as an object; the readable reference
//!   model, kept for unit tests and documentation.
//! * [`LifNeuronArray`] — one whole layer as a structure-of-arrays (flat
//!   `acc` / `spike_count` buffers plus a multi-word enable bitmask, so
//!   hidden layers wider than 64 neurons fit). This is what
//!   [`crate::rtl::RtlCore`] actually runs on the single-image paths —
//!   one array per layer of the topology: the per-cycle inner loops walk
//!   contiguous memory and skip disabled neurons by bit iteration instead
//!   of dispatching through an object array.
//! * [`LifBatchArray`] — one layer × a whole sub-batch: **neuron-major**
//!   accumulator/spike-count planes addressed `plane[j * lanes + b]` plus
//!   a transposed per-neuron lane-enable bitmask, so one weight fetch is
//!   applied to every gated batch lane as a contiguous sweep. This is the
//!   state behind [`crate::rtl::RtlCore::run_fast_batch`].
//!
//! The single-image array and the batch array share one saturating-add
//! kernel ([`sat_add`]) and one toggle-accounting write, so the
//! arithmetic (per-add saturation, Hamming-distance toggle accounting,
//! enable gating) cannot drift between the sequential and the batched
//! engines regardless of plane layout. All three representations are
//! proven state- and activity-equivalent by the property tests below.

// The accumulator datapath is the paper's bit-exactness surface, so new
// arithmetic here must be consciously annotated: each `allow` below cites
// the bound that makes its operations safe (i64 widening before adds,
// indices bounded by plane sizes, u64 event counters).
#![deny(clippy::arithmetic_side_effects)]

use crate::config::{PruneMode, SnnConfig};
use crate::fixed::leak;

use super::power::ActivityCounters;

/// Decoded per-clock control signals driven by the layer controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NeuronCtrl {
    /// Hold: no enable asserted this clock.
    Idle,
    /// `add_en`: integrate `weight` into the accumulator.
    Add { weight: i32 },
    /// `leak_en`: apply the shift-subtract decay.
    Leak,
    /// `fire_en`: evaluate the threshold comparator; fire & hard-reset when
    /// `acc ≥ V_th`.
    FireCheck,
    /// Synchronous reset (new inference window).
    Reset,
}

/// Architectural state of a single neuron core.
#[derive(Debug, Clone)]
pub struct LifNeuronCore {
    /// Membrane accumulator register (sign-extended to i32; physically
    /// `acc_bits` wide).
    acc: i32,
    /// Output spike count register (used by readout and pruning).
    spike_count: u32,
    /// Enable latch: cleared by the controller's pruning mask.
    enabled: bool,
    /// Fired-this-cycle flag (the `Fire` output wire).
    fired: bool,
    cfg_acc_bits: u32,
    cfg_decay_shift: u32,
    cfg_v_th: i32,
    cfg_v_rest: i32,
}

// Bounds: accumulators widen to i64 before any add (`sat_add`), spike
// counts and activity counters are u32/u64 event tallies, and
// `1 << (acc_bits - 1)` is validated ≤ 31 bits by `SnnConfig`.
#[allow(clippy::arithmetic_side_effects)]
impl LifNeuronCore {
    pub fn new(cfg: &SnnConfig) -> Self {
        LifNeuronCore {
            acc: cfg.v_rest,
            spike_count: 0,
            enabled: true,
            fired: false,
            cfg_acc_bits: cfg.acc_bits,
            cfg_decay_shift: cfg.decay_shift,
            cfg_v_th: cfg.v_th,
            cfg_v_rest: cfg.v_rest,
        }
    }

    /// Membrane potential (the accumulator register).
    pub fn acc(&self) -> i32 {
        self.acc
    }

    /// Spike-count register.
    pub fn spike_count(&self) -> u32 {
        self.spike_count
    }

    /// Enable latch value.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The `Fire` wire: did the neuron fire on the last `tick`?
    pub fn fired(&self) -> bool {
        self.fired
    }

    /// Controller drives the enable latch (pruning mask).
    pub fn set_enabled(&mut self, en: bool) {
        self.enabled = en;
    }

    /// Commit one clock edge under `ctrl`. Returns the `Fire` wire value.
    pub fn tick(&mut self, ctrl: NeuronCtrl, act: &mut ActivityCounters) -> bool {
        self.fired = false;
        if !self.enabled && !matches!(ctrl, NeuronCtrl::Reset) {
            // Gated clock: a disabled neuron burns no dynamic power.
            return false;
        }
        match ctrl {
            NeuronCtrl::Idle => {}
            NeuronCtrl::Add { weight } => {
                // Same clamp bound as `SnnConfig::acc_max()`; the integrate
                // itself goes through the shared saturating-adder kernel,
                // so the scalar reference model cannot drift from the
                // array and batch sweeps (pallas-lint rule L3 rejects any
                // accumulator `+` outside the funnel).
                let acc_max = (1i32 << (self.cfg_acc_bits - 1)) - 1;
                let (clamped, saturated) = sat_add(self.acc, weight, acc_max);
                if saturated {
                    act.saturations += 1;
                }
                act.adds += 1;
                self.write_acc(clamped, act);
            }
            NeuronCtrl::Leak => {
                let next = leak(self.acc, self.cfg_decay_shift);
                act.shifts += 1;
                act.adds += 1; // the subtract half of shift-subtract
                self.write_acc(next, act);
            }
            NeuronCtrl::FireCheck => {
                act.compares += 1;
                if self.acc >= self.cfg_v_th {
                    self.fired = true;
                    self.spike_count += 1;
                    act.reg_toggles += 1; // spike-count increment (approx.)
                    self.write_acc(self.cfg_v_rest, act);
                }
            }
            NeuronCtrl::Reset => {
                self.write_acc(self.cfg_v_rest, act);
                self.spike_count = 0;
                self.enabled = true;
                self.fired = false;
            }
        }
        self.fired
    }

    /// Combinational threshold check used in `FireMode::Immediate` during
    /// integration (comparator output without a clock commit).
    pub fn above_threshold(&self) -> bool {
        self.acc >= self.cfg_v_th
    }

    #[inline]
    fn write_acc(&mut self, next: i32, act: &mut ActivityCounters) {
        act.reg_toggles += u64::from(((self.acc as u32) ^ (next as u32)).count_ones());
        self.acc = next;
    }
}

// ---------------------------------------------------------------------------

/// The calibration registers one neuron lane runs under (resolved per
/// layer; shared by every lane of a batch — a batch multiplexes images
/// over one physical layer, so the calibration is common by construction).
#[derive(Debug, Clone, Copy)]
struct LaneParams {
    acc_max: i32,
    decay_shift: u32,
    v_th: i32,
    v_rest: i32,
}

impl LaneParams {
    fn from_cfg(cfg: &SnnConfig) -> Self {
        LaneParams {
            acc_max: cfg.acc_max(),
            decay_shift: cfg.decay_shift,
            v_th: cfg.v_th,
            v_rest: cfg.v_rest,
        }
    }
}

// The sequential lane primitives below are the single-image engines' inner
// loops: no allocation is tolerated here (pallas-lint rule L2), and all
// accumulator arithmetic funnels through `sat_add`/`write_acc_at` (rule
// L3).
// pallas-lint: hot

/// Register write with Hamming-distance toggle accounting — the one
/// `write_acc` every lane-level primitive goes through.
// Bounds: `j` is a bit index derived from the enable mask, < acc.len();
// toggle tallies are u64.
#[allow(clippy::arithmetic_side_effects)]
#[inline(always)]
fn write_acc_at(acc: &mut [i32], j: usize, next: i32, act: &mut ActivityCounters) {
    act.reg_toggles += u64::from(((acc[j] as u32) ^ (next as u32)).count_ones());
    acc[j] = next;
}

/// The saturating adder: `acc + w` clamped to `±acc_max`. Returns the
/// clamped value and whether the clamp engaged. Every integrate path —
/// sequential lane primitives and the batched neuron-major sweeps —
/// funnels through this one kernel so the arithmetic cannot drift
/// between plane layouts.
// Bounds: both operands widen to i64 before the add; the result is
// clamped back into i32 range by construction.
#[allow(clippy::arithmetic_side_effects)]
#[inline(always)]
fn sat_add(acc: i32, w: i32, acc_max: i32) -> (i32, bool) {
    let sum = i64::from(acc) + i64::from(w);
    let clamped = sum.clamp(-i64::from(acc_max), i64::from(acc_max)) as i32;
    (clamped, i64::from(clamped) != sum)
}

/// One BRAM row pulse over one lane: integrate `row[j]` into every
/// *enabled* neuron with per-add saturation (ascending `j`, like the
/// adder-tree fanout).
// Bounds: `wi * 64 + tz` < 64 * enabled.len() = plane size; `m - 1` is
// guarded by `m != 0`; event tallies are u64.
#[allow(clippy::arithmetic_side_effects)]
#[inline]
fn lane_add_row(
    acc: &mut [i32],
    enabled: &[u64],
    row: &[i32],
    p: &LaneParams,
    act: &mut ActivityCounters,
) {
    debug_assert_eq!(row.len(), acc.len());
    for wi in 0..enabled.len() {
        let mut m = enabled[wi];
        while m != 0 {
            let j = wi * 64 + m.trailing_zeros() as usize;
            m &= m - 1;
            let (clamped, saturated) = sat_add(acc[j], row[j], p.acc_max);
            if saturated {
                act.saturations += 1;
            }
            act.adds += 1;
            write_acc_at(acc, j, clamped, act);
        }
    }
}

/// One CSR row pulse over one lane: integrate the row's retained
/// `(column, weight)` entries into every *enabled* neuron, per-add
/// saturation, ascending column order — the event-driven twin of
/// [`lane_add_row`]. Skipped synapses (pruned entries, disabled
/// neurons) record nothing, which is exactly how the BRAM-gating
/// ablation credits pruned neurons: the counters are simply lower. At
/// magnitude threshold 0 the CSR holds every entry, so the visited set,
/// order and arithmetic are identical to the dense walk — bit- and
/// activity-exact.
// Bounds: CSR columns are validated < the layer width at construction;
// event tallies are u64.
#[allow(clippy::arithmetic_side_effects)]
#[inline]
fn lane_add_sparse(
    acc: &mut [i32],
    enabled: &[u64],
    cols: &[u32],
    vals: &[i32],
    p: &LaneParams,
    act: &mut ActivityCounters,
) {
    debug_assert_eq!(cols.len(), vals.len());
    for (&j, &w) in cols.iter().zip(vals) {
        let j = j as usize;
        if (enabled[j / 64] >> (j % 64)) & 1 == 0 {
            continue;
        }
        let (clamped, saturated) = sat_add(acc[j], w, p.acc_max);
        if saturated {
            act.saturations += 1;
        }
        act.adds += 1;
        write_acc_at(acc, j, clamped, act);
    }
}

/// One `Leak` clock over one lane: shift-subtract decay on every enabled
/// neuron.
// Bounds: same mask-walk indices as `lane_add_row`; tallies are u64.
#[allow(clippy::arithmetic_side_effects)]
#[inline]
fn lane_leak(acc: &mut [i32], enabled: &[u64], p: &LaneParams, act: &mut ActivityCounters) {
    for wi in 0..enabled.len() {
        let mut m = enabled[wi];
        while m != 0 {
            let j = wi * 64 + m.trailing_zeros() as usize;
            m &= m - 1;
            let next = leak(acc[j], p.decay_shift);
            act.shifts += 1;
            act.adds += 1; // the subtract half of shift-subtract
            write_acc_at(acc, j, next, act);
        }
    }
}

/// One `Fire` clock over one lane (`FireMode::EndOfStep`): evaluate the
/// threshold comparator of every enabled neuron, setting `fired[j]` and
/// hard-resetting on a crossing. `fired` must be pre-cleared.
// Bounds: same mask-walk indices as `lane_add_row`; spike counts are u32
// tallies bounded by the timestep window.
#[allow(clippy::arithmetic_side_effects)]
fn lane_fire_check(
    acc: &mut [i32],
    spike_count: &mut [u32],
    enabled: &[u64],
    fired: &mut [bool],
    p: &LaneParams,
    act: &mut ActivityCounters,
) {
    debug_assert_eq!(fired.len(), acc.len());
    for wi in 0..enabled.len() {
        let mut m = enabled[wi];
        while m != 0 {
            let j = wi * 64 + m.trailing_zeros() as usize;
            m &= m - 1;
            act.compares += 1;
            if acc[j] >= p.v_th {
                fired[j] = true;
                spike_count[j] += 1;
                act.reg_toggles += 1; // spike-count increment (approx.)
                write_acc_at(acc, j, p.v_rest, act);
            }
        }
    }
}

/// Mid-integration combinational fire over one lane
/// (`FireMode::Immediate`): only neurons whose accumulator is at/above
/// threshold commit a `FireCheck` (and its comparator activity), exactly
/// like the cycle path's `above_threshold()` pre-gate. Returns true when
/// any neuron fired. `fired` must be pre-cleared.
// Bounds: same mask-walk indices and tallies as `lane_fire_check`.
#[allow(clippy::arithmetic_side_effects)]
fn lane_immediate_fire(
    acc: &mut [i32],
    spike_count: &mut [u32],
    enabled: &[u64],
    fired: &mut [bool],
    p: &LaneParams,
    act: &mut ActivityCounters,
) -> bool {
    debug_assert_eq!(fired.len(), acc.len());
    let mut any = false;
    for wi in 0..enabled.len() {
        let mut m = enabled[wi];
        while m != 0 {
            let j = wi * 64 + m.trailing_zeros() as usize;
            m &= m - 1;
            if acc[j] >= p.v_th {
                act.compares += 1;
                fired[j] = true;
                any = true;
                spike_count[j] += 1;
                act.reg_toggles += 1;
                write_acc_at(acc, j, p.v_rest, act);
            }
        }
    }
    any
}
// pallas-lint: end-hot

/// Full enable mask for `n` neurons over `words` mask words.
// Bounds: `words >= 1` by the `.max(1)`, and `rem < 64`.
#[allow(clippy::arithmetic_side_effects)]
fn full_mask_words(n: usize) -> Vec<u64> {
    let words = n.div_ceil(64).max(1);
    let mut mask = vec![u64::MAX; words];
    let rem = n % 64;
    if rem != 0 {
        mask[words - 1] = (1u64 << rem) - 1;
    }
    if n == 0 {
        mask[0] = 0;
    }
    mask
}

// ---------------------------------------------------------------------------

/// One whole layer as a structure-of-arrays.
///
/// State layout: flat `acc` / `spike_count` vectors plus a multi-word
/// enable bitmask (bit `j % 64` of word `j / 64` = neuron `j` enabled), so
/// any layer width works — the paper's output layer has 10 neurons, the
/// MLP-shaped hidden layer 128.
///
/// Every mutator records exactly the [`ActivityCounters`] events the
/// per-neuron [`LifNeuronCore::tick`] would: adds, per-add saturations,
/// shift-subtract leaks, comparator evaluations and the Hamming distance of
/// every register write. Bit-exactness against a `Vec<LifNeuronCore>` is
/// pinned by `array_matches_core_reference` below.
#[derive(Debug, Clone)]
pub struct LifNeuronArray {
    acc: Vec<i32>,
    spike_count: Vec<u32>,
    /// Enable latch words; cleared by the pruning mask.
    enabled: Vec<u64>,
    params: LaneParams,
}

// Bounds: all indices derive from mask-bit positions or `0..n` walks over
// planes sized `n`; arithmetic on accumulators funnels through the lane
// primitives above.
#[allow(clippy::arithmetic_side_effects)]
impl LifNeuronArray {
    /// Build an array sized to the config's *output* width — callers
    /// construct one per layer via [`crate::SnnConfig::layer_config`].
    pub fn new(cfg: &SnnConfig) -> Self {
        let n = cfg.n_outputs();
        LifNeuronArray {
            acc: vec![cfg.v_rest; n],
            spike_count: vec![0; n],
            enabled: full_mask_words(n),
            params: LaneParams::from_cfg(cfg),
        }
    }

    /// Number of neurons.
    pub fn len(&self) -> usize {
        self.acc.len()
    }

    /// True when the layer has no neurons (never for valid configs).
    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }

    /// Membrane potential of neuron `j`.
    pub fn acc(&self, j: usize) -> i32 {
        self.acc[j]
    }

    /// All membrane potentials (borrowed; no allocation).
    pub fn accs(&self) -> &[i32] {
        &self.acc
    }

    /// All membrane potentials (owned copy).
    pub fn membranes(&self) -> Vec<i32> {
        self.acc.clone()
    }

    /// All spike-count registers.
    pub fn spike_counts(&self) -> &[u32] {
        &self.spike_count
    }

    /// Enable latch of neuron `j`.
    pub fn enabled(&self, j: usize) -> bool {
        (self.enabled[j / 64] >> (j % 64)) & 1 == 1
    }

    /// True while at least one neuron is still enabled.
    pub fn any_enabled(&self) -> bool {
        self.enabled.iter().any(|&w| w != 0)
    }

    /// Drive the enable latches from the controller's pruning mask.
    pub fn set_enables(&mut self, enables: &[bool]) {
        debug_assert_eq!(enables.len(), self.acc.len());
        self.enabled.iter_mut().for_each(|w| *w = 0);
        for (j, &e) in enables.iter().enumerate() {
            self.enabled[j / 64] |= u64::from(e) << (j % 64);
        }
    }

    /// Synchronous reset of every neuron (new inference window); re-enables
    /// the whole array, like `NeuronCtrl::Reset` on each core.
    pub fn reset(&mut self, act: &mut ActivityCounters) {
        for j in 0..self.acc.len() {
            write_acc_at(&mut self.acc, j, self.params.v_rest, act);
        }
        self.spike_count.fill(0);
        self.enabled = full_mask_words(self.acc.len());
    }

    /// One BRAM row pulse: integrate `row[j]` into every *enabled* neuron
    /// with per-add saturation (ascending `j`, like the adder-tree fanout).
    #[inline]
    pub fn add_row(&mut self, row: &[i32], act: &mut ActivityCounters) {
        lane_add_row(&mut self.acc, &self.enabled, row, &self.params, act);
    }

    /// One CSR row pulse: integrate the retained `(column, weight)`
    /// entries into every *enabled* neuron (per-add saturation, ascending
    /// column) — see [`lane_add_sparse`] for the dense-equivalence
    /// contract.
    #[inline]
    pub fn add_row_sparse(&mut self, cols: &[u32], vals: &[i32], act: &mut ActivityCounters) {
        lane_add_sparse(&mut self.acc, &self.enabled, cols, vals, &self.params, act);
    }

    /// One `Leak` clock: shift-subtract decay on every enabled neuron.
    #[inline]
    pub fn leak_enabled(&mut self, act: &mut ActivityCounters) {
        lane_leak(&mut self.acc, &self.enabled, &self.params, act);
    }

    /// One `Fire` clock (`FireMode::EndOfStep`): evaluate the threshold
    /// comparator of every enabled neuron, setting `fired[j]` and
    /// hard-resetting on a crossing. `fired` must be pre-cleared.
    pub fn fire_check(&mut self, fired: &mut [bool], act: &mut ActivityCounters) {
        lane_fire_check(
            &mut self.acc,
            &mut self.spike_count,
            &self.enabled,
            fired,
            &self.params,
            act,
        );
    }

    /// Mid-integration combinational fire (`FireMode::Immediate`): only
    /// neurons whose accumulator is at/above threshold commit a `FireCheck`
    /// (and its comparator activity), exactly like the cycle path's
    /// `above_threshold()` pre-gate. Returns true when any neuron fired.
    /// `fired` must be pre-cleared.
    pub fn immediate_fire(&mut self, fired: &mut [bool], act: &mut ActivityCounters) -> bool {
        lane_immediate_fire(
            &mut self.acc,
            &mut self.spike_count,
            &self.enabled,
            fired,
            &self.params,
            act,
        )
    }
}

// ---------------------------------------------------------------------------

// The neuron-major plane funnels below are shared by the whole-array
// sweeps (`LifBatchArray::add_row_lanes` & co.) and the thread-parallel
// neuron-range shards (`LifBatchShard`): one body per event kind, so the
// sharded sweep cannot drift from the serial one. Each funnel takes raw
// plane slices plus the plane geometry and the per-lane activity slice.
// The `m == u64::MAX` arm is the vectorized apply: when a whole mask
// word of lanes is gated on, the bit scan is skipped and the 64 plane
// cells are walked as one contiguous branch-free sweep (the form the
// compiler can vectorize) — taken on every full word of a dense batch,
// and by the batched CSR apply whenever no lane has pruned the entry's
// neuron. Per lane the committed events are identical either way.
// pallas-lint: hot

/// One weight applied to every gated+enabled lane of one neuron's
/// contiguous plane row — the innermost kernel of the batched dense and
/// CSR sweeps, fast-path and bit-scan arms both funneling through
/// [`sat_add`]/[`write_acc_at`].
// Bounds: a full gate word implies all 64 of its lanes exist (enable
// masks zero-pad the partial word), so `base + 64 <= accs.len()`; scan
// indices mirror `lane_add_row`; tallies are u64.
#[allow(clippy::arithmetic_side_effects)]
#[inline(always)]
fn plane_row_add(
    accs: &mut [i32],
    en: &[u64],
    lane_mask: &[u64],
    w: i32,
    acc_max: i32,
    acts: &mut [ActivityCounters],
) {
    for wb in 0..en.len() {
        let gated = lane_mask[wb] & en[wb];
        if gated == u64::MAX {
            let base = wb * 64;
            for b in base..base + 64 {
                let act = &mut acts[b];
                let (next, saturated) = sat_add(accs[b], w, acc_max);
                if saturated {
                    act.saturations += 1;
                }
                act.adds += 1;
                write_acc_at(accs, b, next, act);
            }
        } else {
            let mut m = gated;
            while m != 0 {
                let b = wb * 64 + m.trailing_zeros() as usize;
                m &= m - 1;
                let act = &mut acts[b];
                let (next, saturated) = sat_add(accs[b], w, acc_max);
                if saturated {
                    act.saturations += 1;
                }
                act.adds += 1;
                write_acc_at(accs, b, next, act);
            }
        }
    }
}

/// One `Leak` clock over every gated+enabled lane of an `n`-neuron plane
/// range, neuron-major (`j` outer, lanes inner). Per (neuron, lane) cell
/// this commits exactly the events of `LifBatchArray::leak_enabled`;
/// cells are private to their lane, so the transposed walk order
/// commutes and the per-lane tallies are identical order-invariant sums.
// Bounds: plane indices as in `LifBatchArray`; full-word arm bounded as
// in `plane_row_add`; tallies are u64.
#[allow(clippy::arithmetic_side_effects)]
fn plane_leak_lanes(
    acc: &mut [i32],
    enabled: &[u64],
    lanes: usize,
    lw: usize,
    lane_mask: &[u64],
    decay_shift: u32,
    acts: &mut [ActivityCounters],
) {
    let n = if lanes == 0 { 0 } else { acc.len() / lanes };
    for j in 0..n {
        let accs = &mut acc[j * lanes..(j + 1) * lanes];
        let en = &enabled[j * lw..(j + 1) * lw];
        for wb in 0..lw {
            let gated = lane_mask[wb] & en[wb];
            if gated == u64::MAX {
                let base = wb * 64;
                for b in base..base + 64 {
                    let act = &mut acts[b];
                    let next = leak(accs[b], decay_shift);
                    act.shifts += 1;
                    act.adds += 1; // the subtract half of shift-subtract
                    write_acc_at(accs, b, next, act);
                }
            } else {
                let mut m = gated;
                while m != 0 {
                    let b = wb * 64 + m.trailing_zeros() as usize;
                    m &= m - 1;
                    let act = &mut acts[b];
                    let next = leak(accs[b], decay_shift);
                    act.shifts += 1;
                    act.adds += 1; // the subtract half of shift-subtract
                    write_acc_at(accs, b, next, act);
                }
            }
        }
    }
}

/// One `Fire` clock (`FireMode::EndOfStep`) over every gated+enabled
/// lane of a plane range, writing crossings straight into the
/// neuron-major `step_fired` words (`step_fired[j*lw + b/64]`, bit
/// `b % 64`) instead of a per-lane `fired` buffer. Per (neuron, lane)
/// the comparator/reset/spike-count events match
/// `LifBatchArray::fire_check` exactly; each bit is set at most once per
/// step, so the transposed order commutes.
// Bounds: plane and mask indices as above; spike counts are u32 tallies
// bounded by the timestep window.
#[allow(clippy::arithmetic_side_effects)]
fn plane_fire_check_lanes(
    acc: &mut [i32],
    spike_count: &mut [u32],
    enabled: &[u64],
    lanes: usize,
    lw: usize,
    lane_mask: &[u64],
    p: &LaneParams,
    step_fired: &mut [u64],
    acts: &mut [ActivityCounters],
) {
    let n = if lanes == 0 { 0 } else { acc.len() / lanes };
    for j in 0..n {
        let accs = &mut acc[j * lanes..(j + 1) * lanes];
        let counts = &mut spike_count[j * lanes..(j + 1) * lanes];
        let en = &enabled[j * lw..(j + 1) * lw];
        for wb in 0..lw {
            let mut m = lane_mask[wb] & en[wb];
            while m != 0 {
                let bit = m.trailing_zeros() as usize;
                let b = wb * 64 + bit;
                m &= m - 1;
                let act = &mut acts[b];
                act.compares += 1;
                if accs[b] >= p.v_th {
                    step_fired[j * lw + wb] |= 1u64 << bit;
                    counts[b] += 1;
                    act.reg_toggles += 1; // spike-count increment (approx.)
                    write_acc_at(accs, b, p.v_rest, act);
                }
            }
        }
    }
}

/// The controller's pruning-mask latch over every gated lane of a plane
/// range: a lane whose neuron has reached `after_spikes` spikes drops
/// its enable bit. Clearing is idempotent and a lane only ever reads its
/// own counts / writes its own bits, so per-lane order is immaterial —
/// exactly `LifBatchArray::latch_prune` per lane.
// Bounds: plane and mask indices as above.
#[allow(clippy::arithmetic_side_effects)]
fn plane_latch_prune_lanes(
    spike_count: &[u32],
    enabled: &mut [u64],
    lanes: usize,
    lw: usize,
    lane_mask: &[u64],
    mode: PruneMode,
) {
    let PruneMode::AfterFires { after_spikes } = mode else { return };
    let n = if lanes == 0 { 0 } else { spike_count.len() / lanes };
    for j in 0..n {
        for wb in 0..lw {
            let mut m = lane_mask[wb];
            while m != 0 {
                let bit = m.trailing_zeros() as usize;
                m &= m - 1;
                if spike_count[j * lanes + wb * 64 + bit] >= after_spikes {
                    enabled[j * lw + wb] &= !(1u64 << bit);
                }
            }
        }
    }
}
// pallas-lint: end-hot

/// One layer × a whole sub-batch, **neuron-major**: accumulator and
/// spike-count planes addressed `plane[j * lanes + b]`, so all lanes'
/// copies of neuron `j` sit contiguously. Enables are transposed the
/// same way: per neuron `j`, a multi-word *lane* mask
/// (`enabled[j * lane_words + b/64]`, bit `b % 64` = lane `b` enabled),
/// built with the same word-walk idiom the per-neuron enable mask uses
/// for >64-neuron layers.
///
/// This is the state behind [`crate::rtl::RtlCore::run_fast_batch`]: the
/// batched engine walks each weight row **once** per timestep and hands
/// the row plus a fired-lane mask to [`LifBatchArray::add_row_lanes`] /
/// [`LifBatchArray::add_sparse_lanes`], which apply each visited weight
/// to every gated lane as one contiguous sweep over `plane[j*lanes ..]`.
/// Per lane the visit order (ascending `j`, ascending CSR column) and
/// the arithmetic (the shared [`sat_add`] kernel plus Hamming-distance
/// toggle accounting) are exactly the sequential lane primitives', so
/// each lane stays bit- and activity-identical to a private
/// [`LifNeuronArray`] (pinned by `batch_array_matches_single_arrays`).
///
/// Pruning lives here too ([`LifBatchArray::latch_prune`]): a lane's
/// enable bits are driven from its own spike counts exactly like the
/// controller's mask update, so per-image gating never couples lanes.
#[derive(Debug, Clone)]
pub struct LifBatchArray {
    /// Neurons per lane (the layer width).
    n: usize,
    /// Lane-mask words per neuron (`lanes.div_ceil(64)`, min 1).
    lane_words: usize,
    lanes: usize,
    /// Neuron-major membrane plane: `acc[j * lanes + b]`.
    acc: Vec<i32>,
    /// Neuron-major spike-count plane: `spike_count[j * lanes + b]`.
    spike_count: Vec<u32>,
    /// Transposed enables: `enabled[j * lane_words + b/64]` bit `b % 64`.
    enabled: Vec<u64>,
    params: LaneParams,
}

// Bounds: plane indices are `j * lanes + b` with `j < n`, `b < lanes` and
// planes sized `n * lanes`; lane-mask words mirror the enable-mask idiom;
// accumulator arithmetic funnels through `sat_add`/`write_acc_at`.
#[allow(clippy::arithmetic_side_effects)]
impl LifBatchArray {
    /// Build `lanes` fresh lanes sized to the config's *output* width
    /// (callers construct one per layer via
    /// [`crate::SnnConfig::layer_config`]). Every lane starts reset:
    /// `v_rest` accumulators, zero counts, fully enabled.
    pub fn new(cfg: &SnnConfig, lanes: usize) -> Self {
        let mut arr = LifBatchArray {
            n: cfg.n_outputs(),
            lane_words: 1,
            lanes: 0,
            acc: Vec::new(),
            spike_count: Vec::new(),
            enabled: Vec::new(),
            params: LaneParams::from_cfg(cfg),
        };
        arr.reset(lanes);
        arr
    }

    /// Re-arm the array for a fresh chunk of `lanes` images: `v_rest`
    /// accumulators, zero counts, fully enabled. Reuses the existing
    /// plane allocations (the batch scratch arena calls this once per
    /// chunk instead of constructing fresh arrays), so steady-state
    /// chunks of the same or smaller width allocate nothing.
    pub fn reset(&mut self, lanes: usize) {
        self.lanes = lanes;
        self.lane_words = lanes.div_ceil(64).max(1);
        self.acc.clear();
        self.acc.resize(self.n * lanes, self.params.v_rest);
        self.spike_count.clear();
        self.spike_count.resize(self.n * lanes, 0);
        let lane_mask = full_mask_words(lanes);
        self.enabled.clear();
        self.enabled.reserve(self.n * self.lane_words);
        for _ in 0..self.n {
            self.enabled.extend_from_slice(&lane_mask);
        }
    }

    /// Batch lanes held.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Neurons per lane.
    pub fn width(&self) -> usize {
        self.n
    }

    /// Lane-mask words per neuron.
    pub fn lane_words(&self) -> usize {
        self.lane_words
    }

    /// Membrane potential of neuron `j` on lane `b`.
    pub fn acc_at(&self, b: usize, j: usize) -> i32 {
        self.acc[j * self.lanes + b]
    }

    /// Spike count of neuron `j` on lane `b`.
    pub fn spike_count_at(&self, b: usize, j: usize) -> u32 {
        self.spike_count[j * self.lanes + b]
    }

    /// Enable latch of neuron `j` on lane `b`.
    pub fn enabled_at(&self, b: usize, j: usize) -> bool {
        (self.enabled[j * self.lane_words + b / 64] >> (b % 64)) & 1 == 1
    }

    /// Lane `b`'s membrane potentials, gathered from the strided plane.
    pub fn membranes(&self, b: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.n);
        self.extend_accs(b, &mut out);
        out
    }

    /// Lane `b`'s spike-count registers, gathered from the strided plane.
    pub fn spike_counts(&self, b: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.n);
        self.extend_spike_counts(b, &mut out);
        out
    }

    /// Gather lane `b`'s membranes onto the end of `out` (no allocation
    /// when `out` has capacity).
    pub fn extend_accs(&self, b: usize, out: &mut Vec<i32>) {
        out.extend((0..self.n).map(|j| self.acc[j * self.lanes + b]));
    }

    /// Gather lane `b`'s spike counts onto the end of `out`.
    pub fn extend_spike_counts(&self, b: usize, out: &mut Vec<u32>) {
        out.extend((0..self.n).map(|j| self.spike_count[j * self.lanes + b]));
    }

    /// True while at least one neuron of lane `b` is still enabled — the
    /// per-image BRAM gate.
    pub fn any_enabled(&self, b: usize) -> bool {
        let (wb, bit) = (b / 64, b % 64);
        (0..self.n).any(|j| (self.enabled[j * self.lane_words + wb] >> bit) & 1 == 1)
    }

    // The batched sweeps and single-lane clocks below are the wide-lane
    // engine's inner loops: alloc-free (pallas-lint rule L2), funneled
    // arithmetic (rule L3).
    // pallas-lint: hot

    /// One BRAM row pulse applied to **every lane set in `lane_mask`** in
    /// one sweep: for each neuron `j` (ascending, like the adder-tree
    /// fanout) the gated lanes' accumulators — contiguous at
    /// `acc[j*lanes ..]` — take `row[j]` through the shared [`sat_add`]
    /// kernel. Per lane this is exactly [`lane_add_row`]'s event order
    /// (lanes are independent, so interleaving across lanes commutes);
    /// each lane's adds/saturations/toggles land in its own
    /// `acts[b]`. `lane_mask` must be `lane_words()` long. Funnels
    /// through [`plane_row_add`], whose full-word arm applies the weight
    /// to 64 contiguous lanes without a bit scan.
    #[inline]
    pub fn add_row_lanes(
        &mut self,
        lane_mask: &[u64],
        row: &[i32],
        acts: &mut [ActivityCounters],
    ) {
        debug_assert_eq!(row.len(), self.n);
        debug_assert_eq!(lane_mask.len(), self.lane_words);
        let (lanes, lw, acc_max) = (self.lanes, self.lane_words, self.params.acc_max);
        for (j, &w) in row.iter().enumerate() {
            let accs = &mut self.acc[j * lanes..(j + 1) * lanes];
            let en = &self.enabled[j * lw..(j + 1) * lw];
            plane_row_add(accs, en, lane_mask, w, acc_max, acts);
        }
    }

    /// One CSR row pulse applied to every lane set in `lane_mask` in one
    /// sweep — the event-driven twin of [`add_row_lanes`]: per retained
    /// `(column, weight)` entry (ascending column), all gated lanes whose
    /// neuron is enabled take the weight through [`sat_add`]. Per lane
    /// this is exactly [`lane_add_sparse`]'s visit order and accounting.
    /// Funnels through [`plane_row_add`] too, so a CSR entry whose
    /// neuron no lane has pruned takes the same full-word contiguous
    /// sweep as the dense row — the entry-wise add is no longer scalar
    /// per active lane.
    #[inline]
    pub fn add_sparse_lanes(
        &mut self,
        lane_mask: &[u64],
        cols: &[u32],
        vals: &[i32],
        acts: &mut [ActivityCounters],
    ) {
        debug_assert_eq!(cols.len(), vals.len());
        debug_assert_eq!(lane_mask.len(), self.lane_words);
        let (lanes, lw, acc_max) = (self.lanes, self.lane_words, self.params.acc_max);
        for (&j, &w) in cols.iter().zip(vals) {
            let j = j as usize;
            let accs = &mut self.acc[j * lanes..(j + 1) * lanes];
            let en = &self.enabled[j * lw..(j + 1) * lw];
            plane_row_add(accs, en, lane_mask, w, acc_max, acts);
        }
    }

    /// One BRAM row pulse into lane `b` alone (per-add saturation,
    /// ascending `j`) — the single-lane form used by the per-lane
    /// property tests; the batched sweep goes through
    /// [`add_row_lanes`].
    #[inline]
    pub fn add_row(&mut self, b: usize, row: &[i32], act: &mut ActivityCounters) {
        debug_assert_eq!(row.len(), self.n);
        let (wb, bit) = (b / 64, b % 64);
        for (j, &w) in row.iter().enumerate() {
            if (self.enabled[j * self.lane_words + wb] >> bit) & 1 == 0 {
                continue;
            }
            let idx = j * self.lanes + b;
            let (next, saturated) = sat_add(self.acc[idx], w, self.params.acc_max);
            if saturated {
                act.saturations += 1;
            }
            act.adds += 1;
            write_acc_at(&mut self.acc, idx, next, act);
        }
    }

    /// One CSR row pulse into lane `b` alone (per-add saturation,
    /// ascending column; see [`lane_add_sparse`]).
    #[inline]
    pub fn add_row_sparse(
        &mut self,
        b: usize,
        cols: &[u32],
        vals: &[i32],
        act: &mut ActivityCounters,
    ) {
        debug_assert_eq!(cols.len(), vals.len());
        let (wb, bit) = (b / 64, b % 64);
        for (&j, &w) in cols.iter().zip(vals) {
            let j = j as usize;
            if (self.enabled[j * self.lane_words + wb] >> bit) & 1 == 0 {
                continue;
            }
            let idx = j * self.lanes + b;
            let (next, saturated) = sat_add(self.acc[idx], w, self.params.acc_max);
            if saturated {
                act.saturations += 1;
            }
            act.adds += 1;
            write_acc_at(&mut self.acc, idx, next, act);
        }
    }

    /// One `Leak` clock on lane `b`: shift-subtract decay on every
    /// enabled neuron, ascending `j` like [`lane_leak`].
    #[inline]
    pub fn leak_enabled(&mut self, b: usize, act: &mut ActivityCounters) {
        let (wb, bit) = (b / 64, b % 64);
        for j in 0..self.n {
            if (self.enabled[j * self.lane_words + wb] >> bit) & 1 == 0 {
                continue;
            }
            let idx = j * self.lanes + b;
            let next = leak(self.acc[idx], self.params.decay_shift);
            act.shifts += 1;
            act.adds += 1; // the subtract half of shift-subtract
            write_acc_at(&mut self.acc, idx, next, act);
        }
    }

    /// One `Fire` clock on lane `b` (`FireMode::EndOfStep`); `fired` must
    /// be pre-cleared and `width()` long. Event order matches
    /// [`lane_fire_check`].
    pub fn fire_check(&mut self, b: usize, fired: &mut [bool], act: &mut ActivityCounters) {
        debug_assert_eq!(fired.len(), self.n);
        let (wb, bit) = (b / 64, b % 64);
        for j in 0..self.n {
            if (self.enabled[j * self.lane_words + wb] >> bit) & 1 == 0 {
                continue;
            }
            act.compares += 1;
            let idx = j * self.lanes + b;
            if self.acc[idx] >= self.params.v_th {
                fired[j] = true;
                self.spike_count[idx] += 1;
                act.reg_toggles += 1; // spike-count increment (approx.)
                write_acc_at(&mut self.acc, idx, self.params.v_rest, act);
            }
        }
    }

    /// Mid-integration combinational fire on lane `b`
    /// (`FireMode::Immediate`); `fired` must be pre-cleared. Event order
    /// matches [`lane_immediate_fire`].
    pub fn immediate_fire(
        &mut self,
        b: usize,
        fired: &mut [bool],
        act: &mut ActivityCounters,
    ) -> bool {
        debug_assert_eq!(fired.len(), self.n);
        let (wb, bit) = (b / 64, b % 64);
        let mut any = false;
        for j in 0..self.n {
            if (self.enabled[j * self.lane_words + wb] >> bit) & 1 == 0 {
                continue;
            }
            let idx = j * self.lanes + b;
            if self.acc[idx] >= self.params.v_th {
                act.compares += 1;
                fired[j] = true;
                any = true;
                self.spike_count[idx] += 1;
                act.reg_toggles += 1;
                write_acc_at(&mut self.acc, idx, self.params.v_rest, act);
            }
        }
        any
    }
    // pallas-lint: end-hot

    /// Drive lane `b`'s enable bits from its own spike counts — the
    /// controller's pruning-mask update, applied at the same latch points
    /// the sequential engine applies it (fire clocks, and mid-walk
    /// Immediate fires). Clearing is idempotent, exactly like the
    /// controller's `enabled_count` guard.
    pub fn latch_prune(&mut self, b: usize, mode: PruneMode) {
        let PruneMode::AfterFires { after_spikes } = mode else { return };
        let (wb, bit) = (b / 64, b % 64);
        for j in 0..self.n {
            if self.spike_count[j * self.lanes + b] >= after_spikes {
                self.enabled[j * self.lane_words + wb] &= !(1u64 << bit);
            }
        }
    }

    /// Split the array into disjoint contiguous neuron-range shards for
    /// the thread-parallel sweep. `ranges` must tile `[0, width())` in
    /// ascending order (`[j0, j1)` pairs, each starting where the last
    /// ended); because every plane is neuron-major, each range owns a
    /// contiguous `&mut` slice of each plane, carved with
    /// `split_at_mut` so the borrow checker proves disjointness — no
    /// `unsafe`, no locks. Allocates only the shard Vec (planes are
    /// borrowed in place); called once per layer sweep, outside the
    /// per-row hot loops.
    // Bounds: range arithmetic is asserted to tile the plane; slice
    // lengths are `len * lanes` / `len * lane_words` by construction.
    #[allow(clippy::arithmetic_side_effects)]
    pub fn shards(&mut self, ranges: &[(usize, usize)]) -> Vec<LifBatchShard<'_>> {
        let (lanes, lw, params) = (self.lanes, self.lane_words, self.params);
        let mut out = Vec::with_capacity(ranges.len());
        let mut acc = &mut self.acc[..];
        let mut spike_count = &mut self.spike_count[..];
        let mut enabled = &mut self.enabled[..];
        let mut consumed = 0usize;
        for &(j0, j1) in ranges {
            assert!(
                j0 == consumed && j1 >= j0 && j1 <= self.n,
                "shard ranges must tile [0, width()) in order: got [{j0}, {j1}) at {consumed}"
            );
            let len = j1 - j0;
            let (a, rest) = std::mem::take(&mut acc).split_at_mut(len * lanes);
            acc = rest;
            let (s, rest) = std::mem::take(&mut spike_count).split_at_mut(len * lanes);
            spike_count = rest;
            let (e, rest) = std::mem::take(&mut enabled).split_at_mut(len * lw);
            enabled = rest;
            out.push(LifBatchShard {
                j0,
                n: len,
                lanes,
                lane_words: lw,
                acc: a,
                spike_count: s,
                enabled: e,
                params,
            });
            consumed = j1;
        }
        out
    }

    /// Test-only `(pointer, capacity)` fingerprint of the three state
    /// planes — equal fingerprints across `reset` calls prove the planes
    /// were re-armed in place, not re-allocated.
    #[cfg(test)]
    pub(crate) fn plane_fingerprint(&self) -> [(usize, usize); 3] {
        [
            (self.acc.as_ptr() as usize, self.acc.capacity()),
            (self.spike_count.as_ptr() as usize, self.spike_count.capacity()),
            (self.enabled.as_ptr() as usize, self.enabled.capacity()),
        ]
    }
}

// ---------------------------------------------------------------------------

/// A disjoint contiguous neuron-range view `[j0, j0+width)` of one
/// [`LifBatchArray`] — the unit of the thread-parallel batched sweep.
/// Neuron-major planes make the range a private plane slice, so
/// [`LifBatchArray::shards`] hands each worker thread a `&mut` shard
/// with zero shared mutable state. Every shard method funnels through
/// the same plane kernels as the whole-array sweeps ([`plane_row_add`]
/// and friends), so a sharded walk commits bit-identical
/// per-(neuron, lane) event sequences — the thread-count-invariance
/// property tests in `rtl::core` pin this end to end.
#[derive(Debug)]
pub struct LifBatchShard<'a> {
    /// First global neuron index of the range (CSR columns are global).
    j0: usize,
    /// Neurons in the range.
    n: usize,
    lanes: usize,
    lane_words: usize,
    acc: &'a mut [i32],
    spike_count: &'a mut [u32],
    enabled: &'a mut [u64],
    params: LaneParams,
}

// Bounds: local plane indices are `(j - j0) * lanes + b` with slices
// sized by `shards`; arithmetic funnels through the shared plane
// kernels.
#[allow(clippy::arithmetic_side_effects)]
impl LifBatchShard<'_> {
    /// First global neuron index covered.
    pub fn start(&self) -> usize {
        self.j0
    }

    /// Neurons covered.
    pub fn width(&self) -> usize {
        self.n
    }

    // The shard sweeps are the parallel engine's inner loops: alloc-free
    // (pallas-lint rule L2), funneled arithmetic (rule L3).
    // pallas-lint: hot

    /// One BRAM row pulse over the range: `row` is the weight row
    /// already sliced to `[j0, j0+width)`. Same kernel as
    /// [`LifBatchArray::add_row_lanes`], restricted to the range.
    #[inline]
    pub fn add_row_lanes(
        &mut self,
        lane_mask: &[u64],
        row: &[i32],
        acts: &mut [ActivityCounters],
    ) {
        debug_assert_eq!(row.len(), self.n);
        let (lanes, lw, acc_max) = (self.lanes, self.lane_words, self.params.acc_max);
        for (j, &w) in row.iter().enumerate() {
            let accs = &mut self.acc[j * lanes..(j + 1) * lanes];
            let en = &self.enabled[j * lw..(j + 1) * lw];
            plane_row_add(accs, en, lane_mask, w, acc_max, acts);
        }
    }

    /// One CSR row pulse over the range: `cols`/`vals` are the row's
    /// entries already partitioned to global columns in
    /// `[j0, j0+width)` (see `SparseLayer::row_span`). Same kernel as
    /// [`LifBatchArray::add_sparse_lanes`], with columns rebased.
    #[inline]
    pub fn add_sparse_lanes(
        &mut self,
        lane_mask: &[u64],
        cols: &[u32],
        vals: &[i32],
        acts: &mut [ActivityCounters],
    ) {
        debug_assert_eq!(cols.len(), vals.len());
        let (lanes, lw, acc_max) = (self.lanes, self.lane_words, self.params.acc_max);
        for (&j, &w) in cols.iter().zip(vals) {
            let j = j as usize - self.j0;
            let accs = &mut self.acc[j * lanes..(j + 1) * lanes];
            let en = &self.enabled[j * lw..(j + 1) * lw];
            plane_row_add(accs, en, lane_mask, w, acc_max, acts);
        }
    }

    /// One `Leak` clock over every gated lane of the range.
    #[inline]
    pub fn leak_lanes(&mut self, lane_mask: &[u64], acts: &mut [ActivityCounters]) {
        plane_leak_lanes(
            self.acc,
            self.enabled,
            self.lanes,
            self.lane_words,
            lane_mask,
            self.params.decay_shift,
            acts,
        );
    }

    /// One `Fire` clock (`FireMode::EndOfStep`) over every gated lane of
    /// the range, setting crossings in `step_fired` — the *range's*
    /// slice of the layer's neuron-major step-fired words, indexed by
    /// local neuron (`(j - j0) * lane_words + b/64`).
    #[inline]
    pub fn fire_check_lanes(
        &mut self,
        lane_mask: &[u64],
        step_fired: &mut [u64],
        acts: &mut [ActivityCounters],
    ) {
        debug_assert_eq!(step_fired.len(), self.n * self.lane_words);
        plane_fire_check_lanes(
            self.acc,
            self.spike_count,
            self.enabled,
            self.lanes,
            self.lane_words,
            lane_mask,
            &self.params,
            step_fired,
            acts,
        );
    }

    /// The pruning-mask latch over every gated lane of the range.
    #[inline]
    pub fn latch_prune_lanes(&mut self, lane_mask: &[u64], mode: PruneMode) {
        plane_latch_prune_lanes(
            self.spike_count,
            self.enabled,
            self.lanes,
            self.lane_words,
            lane_mask,
            mode,
        );
    }
    // pallas-lint: end-hot
}

// Test arithmetic (sizes, indices) is bounded by the tiny generated cases.
#[allow(clippy::arithmetic_side_effects)]
#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SnnConfig {
        SnnConfig { v_th: 10, decay_shift: 1, acc_bits: 16, ..SnnConfig::paper() }
    }

    #[test]
    fn add_leak_fire_sequence() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 7 }, &mut act);
        assert_eq!(n.acc(), 7);
        n.tick(NeuronCtrl::Leak, &mut act);
        assert_eq!(n.acc(), 4); // 7 - (7>>1)=3
        n.tick(NeuronCtrl::Add { weight: 7 }, &mut act);
        assert_eq!(n.acc(), 11);
        let fired = n.tick(NeuronCtrl::FireCheck, &mut act);
        assert!(fired);
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 1);
    }

    #[test]
    fn disabled_neuron_is_inert_and_free() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.set_enabled(false);
        let before = act;
        n.tick(NeuronCtrl::Add { weight: 100 }, &mut act);
        n.tick(NeuronCtrl::Leak, &mut act);
        n.tick(NeuronCtrl::FireCheck, &mut act);
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 0);
        assert_eq!(act, before, "disabled neuron must record zero activity");
    }

    #[test]
    fn reset_reenables() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 25 }, &mut act);
        n.tick(NeuronCtrl::FireCheck, &mut act);
        n.set_enabled(false);
        n.tick(NeuronCtrl::Reset, &mut act);
        assert!(n.enabled());
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 0);
    }

    #[test]
    fn saturation_is_counted() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&SnnConfig { acc_bits: 8, v_th: 100, ..cfg() });
        for _ in 0..3 {
            n.tick(NeuronCtrl::Add { weight: 120 }, &mut act);
        }
        // 120, then 240 -> clamp 127, then 127+120 -> clamp.
        assert_eq!(n.acc(), 127);
        assert_eq!(act.saturations, 2);
    }

    #[test]
    fn negative_membrane_decays_up() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: -9 }, &mut act);
        assert_eq!(n.acc(), -9);
        n.tick(NeuronCtrl::Leak, &mut act);
        // -9 - (-9>>1) = -9 - (-5) = -4
        assert_eq!(n.acc(), -4);
    }

    #[test]
    fn toggle_counting_tracks_hamming_distance() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 0b1111 }, &mut act);
        assert_eq!(act.reg_toggles, 4); // 0 -> 0b1111 toggles 4 bits
    }

    /// The SoA array and a `Vec<LifNeuronCore>` must stay state- and
    /// activity-identical under random command streams — the foundation of
    /// the RTL core's fast path.
    #[test]
    fn array_matches_core_reference() {
        use crate::testutil::PropRunner;

        PropRunner::new("lif_array_equiv", 60).run(|g| {
            // Mostly narrow arrays, sometimes wider than one mask word so
            // the multi-word enable iteration is exercised too.
            let n = if g.rng.below(4) == 0 {
                g.rng.range_i32(65, 140) as usize
            } else {
                g.rng.range_i32(1, 12) as usize
            };
            let cfg = SnnConfig {
                topology: vec![784, n],
                v_th: g.rng.range_i32(5, 60),
                decay_shift: g.rng.range_i32(1, 4) as u32,
                // Narrow accumulator so per-add saturation gets exercised.
                acc_bits: g.rng.range_i32(8, 16) as u32,
                ..SnnConfig::paper()
            };
            let mut array = LifNeuronArray::new(&cfg);
            let mut cores: Vec<LifNeuronCore> =
                (0..n).map(|_| LifNeuronCore::new(&cfg)).collect();
            let mut act_a = ActivityCounters::default();
            let mut act_c = ActivityCounters::default();
            let mut fired_a = vec![false; n];

            for _ in 0..120 {
                match g.rng.below(6) {
                    0 => {
                        let row = g.vec_i32(n, -120, 120);
                        array.add_row(&row, &mut act_a);
                        for (j, c) in cores.iter_mut().enumerate() {
                            c.tick(NeuronCtrl::Add { weight: row[j] }, &mut act_c);
                        }
                    }
                    1 => {
                        array.leak_enabled(&mut act_a);
                        for c in cores.iter_mut() {
                            c.tick(NeuronCtrl::Leak, &mut act_c);
                        }
                    }
                    2 => {
                        fired_a.fill(false);
                        array.fire_check(&mut fired_a, &mut act_a);
                        for (j, c) in cores.iter_mut().enumerate() {
                            let f = c.tick(NeuronCtrl::FireCheck, &mut act_c);
                            assert_eq!(fired_a[j], f, "fire wire diverges at {j}");
                        }
                    }
                    3 => {
                        fired_a.fill(false);
                        array.immediate_fire(&mut fired_a, &mut act_a);
                        for (j, c) in cores.iter_mut().enumerate() {
                            let mut f = false;
                            if c.enabled() && c.above_threshold() {
                                f = c.tick(NeuronCtrl::FireCheck, &mut act_c);
                            }
                            assert_eq!(fired_a[j], f, "immediate fire diverges at {j}");
                        }
                    }
                    4 => {
                        let enables: Vec<bool> =
                            (0..n).map(|_| g.rng.next_u32() & 1 == 1).collect();
                        array.set_enables(&enables);
                        for (c, &e) in cores.iter_mut().zip(&enables) {
                            c.set_enabled(e);
                        }
                    }
                    _ => {
                        array.reset(&mut act_a);
                        for c in cores.iter_mut() {
                            c.tick(NeuronCtrl::Reset, &mut act_c);
                        }
                    }
                }
                for (j, c) in cores.iter().enumerate() {
                    assert_eq!(array.acc(j), c.acc(), "membrane diverges at {j}");
                    assert_eq!(array.spike_counts()[j], c.spike_count(), "count at {j}");
                    assert_eq!(array.enabled(j), c.enabled(), "enable at {j}");
                }
                assert_eq!(act_a, act_c, "activity counters diverge");
            }
        });
    }

    /// The CSR row pulse at threshold 0 must be state- and
    /// activity-identical to the dense row pulse — the per-entry
    /// foundation of the sparse sweep's bit-exactness — and above
    /// threshold 0 it must apply exactly the surviving subset.
    #[test]
    fn sparse_add_matches_dense_at_threshold_zero() {
        use crate::fixed::{SparseWeightLayer, WeightMatrix};
        use crate::testutil::PropRunner;

        PropRunner::new("lane_sparse_equiv", 60).run(|g| {
            let n = if g.rng.below(4) == 0 {
                g.rng.range_i32(65, 120) as usize
            } else {
                g.rng.range_i32(1, 14) as usize
            };
            let cfg = SnnConfig {
                topology: vec![784, n],
                v_th: g.rng.range_i32(5, 60),
                decay_shift: g.rng.range_i32(1, 4) as u32,
                acc_bits: g.rng.range_i32(8, 16) as u32,
                ..SnnConfig::paper()
            };
            let rows = 6usize;
            let m = WeightMatrix::from_rows(rows, n, 9, g.vec_i32(rows * n, -120, 120)).unwrap();
            let csr0 = SparseWeightLayer::from_dense(&m, 0);

            let mut dense = LifNeuronArray::new(&cfg);
            let mut sparse = LifNeuronArray::new(&cfg);
            let mut act_d = ActivityCounters::default();
            let mut act_s = ActivityCounters::default();
            let mut fired = vec![false; n];
            for round in 0..40 {
                let i = g.rng.below(rows as u32) as usize;
                let (cols, vals) = csr0.row(i);
                dense.add_row(m.row(i), &mut act_d);
                sparse.add_row_sparse(cols, vals, &mut act_s);
                if round % 7 == 3 {
                    // Random pruning mask: the enabled-gating must agree.
                    let enables: Vec<bool> =
                        (0..n).map(|_| g.rng.next_u32() & 1 == 1).collect();
                    dense.set_enables(&enables);
                    sparse.set_enables(&enables);
                }
                if round % 5 == 2 {
                    dense.leak_enabled(&mut act_d);
                    sparse.leak_enabled(&mut act_s);
                    fired.fill(false);
                    dense.fire_check(&mut fired, &mut act_d);
                    fired.fill(false);
                    sparse.fire_check(&mut fired, &mut act_s);
                }
                assert_eq!(dense.accs(), sparse.accs(), "membranes diverge");
                assert_eq!(act_d, act_s, "activity diverges at threshold 0");
            }

            // Above threshold 0 the sparse pulse applies exactly the
            // surviving entries: fewer (or equal) adds, and the membrane
            // equals a dense pulse of the pruned plane.
            let th = g.rng.range_i32(1, 100);
            let csr = SparseWeightLayer::from_dense(&m, th);
            let pruned = csr.to_dense();
            let mut via_sparse = LifNeuronArray::new(&cfg);
            let mut via_pruned_dense = LifNeuronArray::new(&cfg);
            let mut a_s = ActivityCounters::default();
            let mut a_d = ActivityCounters::default();
            for i in 0..rows {
                let (cols, vals) = csr.row(i);
                via_sparse.add_row_sparse(cols, vals, &mut a_s);
                via_pruned_dense.add_row(pruned.row(i), &mut a_d);
            }
            assert_eq!(via_sparse.accs(), via_pruned_dense.accs());
            assert!(
                a_s.adds <= a_d.adds,
                "sparse must never add more than the pruned dense plane"
            );
            assert_eq!(a_s.adds as usize, csr.nnz(), "one add per retained synapse");
        });
    }

    /// Every lane of a [`LifBatchArray`] must stay state- and
    /// activity-identical to a private [`LifNeuronArray`] driven with the
    /// same command stream — lanes are independent by construction, and a
    /// random interleaving of per-lane commands must never couple them.
    /// This is the foundation of `RtlCore::run_fast_batch`'s bit-exactness.
    #[test]
    fn batch_array_matches_single_arrays() {
        use crate::testutil::PropRunner;

        PropRunner::new("lif_batch_equiv", 40).run(|g| {
            // Mostly narrow batches, sometimes wider than one lane-mask
            // word so the multi-word (transposed) lane masks and the
            // wide sweeps' second mask word are exercised.
            let lanes = if g.rng.below(4) == 0 {
                g.rng.range_i32(65, 80) as usize
            } else {
                g.rng.range_i32(1, 7) as usize
            };
            // Mostly narrow layers, sometimes wider than one mask word
            // (kept narrow when the batch is wide to bound the cost).
            let n = if lanes <= 64 && g.rng.below(4) == 0 {
                g.rng.range_i32(65, 100) as usize
            } else {
                g.rng.range_i32(1, 14) as usize
            };
            let cfg = SnnConfig {
                topology: vec![784, n],
                v_th: g.rng.range_i32(5, 60),
                decay_shift: g.rng.range_i32(1, 4) as u32,
                acc_bits: g.rng.range_i32(8, 16) as u32,
                ..SnnConfig::paper()
            };
            let prune = *g.choice(&[
                PruneMode::Off,
                PruneMode::AfterFires { after_spikes: 1 },
                PruneMode::AfterFires { after_spikes: 2 },
            ]);
            let mut batch = LifBatchArray::new(&cfg, lanes);
            let mut singles: Vec<LifNeuronArray> =
                (0..lanes).map(|_| LifNeuronArray::new(&cfg)).collect();
            let mut act_b: Vec<ActivityCounters> =
                vec![ActivityCounters::default(); lanes];
            let mut act_s: Vec<ActivityCounters> =
                vec![ActivityCounters::default(); lanes];
            let mut fired_b = vec![false; n];
            let mut fired_s = vec![false; n];

            let lane_words = lanes.div_ceil(64).max(1);
            let mut lane_mask = vec![0u64; lane_words];

            for _ in 0..100 {
                // One random command on one random lane per round: the
                // interleaving across lanes is itself randomized. Two
                // extra commands drive the *wide* sweeps across a random
                // lane subset, mirrored lane-by-lane on the singles.
                let b = g.rng.below(lanes as u32) as usize;
                match g.rng.below(7) {
                    0 => {
                        let row = g.vec_i32(n, -120, 120);
                        batch.add_row(b, &row, &mut act_b[b]);
                        singles[b].add_row(&row, &mut act_s[b]);
                    }
                    5 => {
                        // Wide dense sweep over a random lane subset.
                        let row = g.vec_i32(n, -120, 120);
                        lane_mask.iter_mut().for_each(|w| *w = 0);
                        for lane in 0..lanes {
                            if g.rng.next_u32() & 1 == 1 {
                                lane_mask[lane / 64] |= 1u64 << (lane % 64);
                            }
                        }
                        batch.add_row_lanes(&lane_mask, &row, &mut act_b);
                        for (lane, single) in singles.iter_mut().enumerate() {
                            if (lane_mask[lane / 64] >> (lane % 64)) & 1 == 1 {
                                single.add_row(&row, &mut act_s[lane]);
                            }
                        }
                    }
                    6 => {
                        // Wide CSR sweep over a random lane subset.
                        let mut cols = Vec::new();
                        let mut vals = Vec::new();
                        for j in 0..n {
                            if g.rng.next_u32() & 1 == 1 {
                                cols.push(j as u32);
                                vals.push(g.rng.range_i32(-120, 120));
                            }
                        }
                        lane_mask.iter_mut().for_each(|w| *w = 0);
                        for lane in 0..lanes {
                            if g.rng.next_u32() & 1 == 1 {
                                lane_mask[lane / 64] |= 1u64 << (lane % 64);
                            }
                        }
                        batch.add_sparse_lanes(&lane_mask, &cols, &vals, &mut act_b);
                        for lane in 0..lanes {
                            if (lane_mask[lane / 64] >> (lane % 64)) & 1 == 1 {
                                singles[lane].add_row_sparse(&cols, &vals, &mut act_s[lane]);
                            }
                        }
                    }
                    1 => {
                        batch.leak_enabled(b, &mut act_b[b]);
                        singles[b].leak_enabled(&mut act_s[b]);
                    }
                    2 => {
                        fired_b.fill(false);
                        fired_s.fill(false);
                        batch.fire_check(b, &mut fired_b, &mut act_b[b]);
                        singles[b].fire_check(&mut fired_s, &mut act_s[b]);
                        assert_eq!(fired_b, fired_s, "fire pattern diverges on lane {b}");
                    }
                    3 => {
                        fired_b.fill(false);
                        fired_s.fill(false);
                        let any_b = batch.immediate_fire(b, &mut fired_b, &mut act_b[b]);
                        let any_s = singles[b].immediate_fire(&mut fired_s, &mut act_s[b]);
                        assert_eq!(any_b, any_s, "immediate any-fire diverges on {b}");
                        assert_eq!(fired_b, fired_s, "immediate pattern diverges on {b}");
                    }
                    _ => {
                        // Prune latch: the single array mirrors the
                        // controller's mask update from its own counts.
                        batch.latch_prune(b, prune);
                        if let PruneMode::AfterFires { after_spikes } = prune {
                            let enables: Vec<bool> = (0..n)
                                .map(|j| {
                                    singles[b].enabled(j)
                                        && singles[b].spike_counts()[j] < after_spikes
                                })
                                .collect();
                            singles[b].set_enables(&enables);
                        }
                    }
                }
                for (lane, single) in singles.iter().enumerate() {
                    assert_eq!(batch.membranes(lane), single.accs(), "membranes, lane {lane}");
                    assert_eq!(
                        batch.spike_counts(lane),
                        single.spike_counts(),
                        "counts, lane {lane}"
                    );
                    for j in 0..n {
                        assert_eq!(
                            batch.enabled_at(lane, j),
                            single.enabled(j),
                            "enable {j}, lane {lane}"
                        );
                        assert_eq!(batch.acc_at(lane, j), single.acc(j), "acc_at {j}/{lane}");
                        assert_eq!(
                            batch.spike_count_at(lane, j),
                            single.spike_counts()[j],
                            "count_at {j}/{lane}"
                        );
                    }
                    assert_eq!(batch.any_enabled(lane), single.any_enabled());
                    assert_eq!(act_b[lane], act_s[lane], "activity, lane {lane}");
                }
            }
        });
    }
}
