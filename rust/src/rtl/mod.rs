//! Cycle-accurate RTL-equivalent simulator of the paper's SystemVerilog
//! core (Figs. 1–3).
//!
//! This module is the substitution for the authors' Vivado simulation (see
//! DESIGN.md §2): a structural, two-phase-clocked model in which every
//! register, enable signal and datapath operation of the published design
//! exists and updates on the same clock schedule the RTL describes.
//!
//! ## Microarchitecture (as in the paper)
//!
//! ```text
//!             ┌────────────────────────────────────────────────┐
//!             │                LayerController (FSM)            │
//!             │  Idle → Integrate(pixel 0..783) → Leak → Fire   │
//!             │    ↑                                    │       │
//!             │    └──────────── next timestep ─────────┘       │
//!             └──┬─────────────┬───────────────┬────────────────┘
//!      en_0..en_9│    pixel idx│               │spike_reg, prune mask
//!         ┌──────▼─────┐ ┌─────▼──────┐  ┌─────▼─────┐
//!         │ LIF core ×10│ │ Poisson    │  │ Weight    │
//!         │ acc, adder, │ │ encoder    │  │ BRAM      │
//!         │ >>n, cmp    │ │ (xorshift) │  │ (9-bit)   │
//!         └─────────────┘ └────────────┘  └───────────┘
//! ```
//!
//! Per timestep the controller walks the 784 pixels one per clock
//! (`Integrate`), stepping that pixel's xorshift32 register and — only when
//! the comparator emits a spike — fetching the pixel's weight row from BRAM
//! and pulsing the add-enable of every still-enabled neuron core
//! (event-driven gating: no spike, no switching). A single `Leak` cycle
//! applies the shift-subtract to all neurons in parallel (or one leak cycle
//! per image row in [`crate::config::LeakMode::PerRow`] mode, §III-B2), and
//! a `Fire` cycle evaluates the threshold comparators, latches output
//! spikes into the spike register, hard-resets fired accumulators and
//! updates the active-pruning mask (§III-D).
//!
//! With [`crate::config::FireMode::Immediate`] the comparator instead acts
//! combinationally during integration (§III-B3 "continuously monitors"),
//! firing and resetting mid-phase.
//!
//! Every register write records its Hamming distance into
//! [`power::ActivityCounters`]; [`power::EnergyModel`] converts activity to
//! energy with documented 45 nm per-op constants, which is how the pruning
//! mechanism's power claim is quantified.
//!
//! ## Execution engines
//!
//! The core offers three engines over one architecture: the cycle-stepped
//! FSM walk ([`RtlCore::tick_cycle`] / [`RtlCore::run`]), the
//! batched-timestep fast path ([`RtlCore::run_fast`]) and the
//! batch-parallel fast path ([`RtlCore::run_fast_batch`]) that runs a
//! whole sub-batch of images through one timestep sweep, walking each
//! weight row once per timestep for the entire batch. The fast path is
//! bit- and activity-exact with the cycle path, and the batched path is
//! bit-exact with the fast path image for image (both property-tested
//! across all mode combinations) — see EXPERIMENTS.md §Perf / §Batch for
//! the equivalence arguments and measured speedups.
//!
//! ## Equivalence to the behavioral model
//!
//! In `FireMode::EndOfStep` + `LeakMode::PerTimestep` the core is
//! step-equivalent to [`crate::snn::BehavioralNet`] (same membrane value
//! after every timestep, same spikes, same decision) *provided no
//! accumulator saturation event occurs* — the RTL saturates per-add, the
//! architectural spec saturates once per step. Saturation events are
//! counted and asserted zero in the equivalence tests; with the paper's
//! V_th = 128 and 9-bit weights the accumulator never approaches the
//! 24-bit rails.

mod controller;
mod core;
mod encoder;
mod lif_neuron;
pub mod power;
mod vcd;

pub use controller::{CtrlState, LayerController};
pub use self::core::{batch_chunks, RtlCore, RtlResult, BATCH_LANES};
pub use encoder::RtlPoissonEncoder;
pub use lif_neuron::{LifBatchArray, LifNeuronArray, LifNeuronCore, NeuronCtrl};
pub use power::{ActivityCounters, EnergyModel, EnergyReport};
pub use vcd::VcdWriter;
