//! Switching-activity counters and the energy model.
//!
//! Dynamic power in CMOS is `α·C·V²·f` — proportional to switching
//! activity. The simulator therefore counts every architectural event that
//! toggles silicon (adds, shifts, compares, BRAM reads, PRNG steps, and the
//! Hamming distance of every register write) and converts the totals to
//! energy with per-op constants from Horowitz, *"Computing's energy
//! problem (and what we can do about it)"*, ISSCC 2014 (45 nm, scaled to
//! the operand widths of this design):
//!
//! | event | constant | basis |
//! |---|---|---|
//! | 24-bit add | 0.075 pJ | 32-bit int add 0.1 pJ × 24/32 |
//! | barrel shift | 0.024 pJ | ~⅓ of an add (mux tree) |
//! | 8/24-bit compare | 0.030 pJ | subtractor-width scaled |
//! | BRAM row read (90 bit) | 2.5 pJ | 8 KB SRAM read 5 pJ/word, half-width row |
//! | xorshift32 step | 0.060 pJ | three 32-bit XOR stages + register |
//! | register bit toggle | 0.0005 pJ | flop + local clock load |
//!
//! Absolute joules are estimates; *ratios* between configurations (pruning
//! on/off, ANN MACs vs SNN adds) are the quantity the paper's Table II
//! argues about, and those are activity-count ratios, which the simulator
//! measures exactly.

/// Raw switching-activity event counts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ActivityCounters {
    /// Accumulator adds actually performed (event-driven: only on spikes,
    /// only for enabled neurons).
    pub adds: u64,
    /// Leak shift-subtract operations.
    pub shifts: u64,
    /// Comparator evaluations (encoder 8-bit + threshold 24-bit).
    pub compares: u64,
    /// Weight BRAM row reads.
    pub bram_reads: u64,
    /// xorshift32 register updates.
    pub prng_steps: u64,
    /// Total Hamming distance of register writes (bits toggled).
    pub reg_toggles: u64,
    /// Clock cycles elapsed.
    pub cycles: u64,
    /// Saturation events in any accumulator (expected 0 in the paper's
    /// operating regime; asserted by equivalence tests).
    pub saturations: u64,
}

impl ActivityCounters {
    /// Element-wise sum (for aggregating across images).
    pub fn add(&mut self, o: &ActivityCounters) {
        self.adds += o.adds;
        self.shifts += o.shifts;
        self.compares += o.compares;
        self.bram_reads += o.bram_reads;
        self.prng_steps += o.prng_steps;
        self.reg_toggles += o.reg_toggles;
        self.cycles += o.cycles;
        self.saturations += o.saturations;
    }

    /// Element-wise difference against an earlier snapshot (per-window
    /// deltas from cumulative counters).
    pub fn since(&self, start: &ActivityCounters) -> ActivityCounters {
        ActivityCounters {
            adds: self.adds - start.adds,
            shifts: self.shifts - start.shifts,
            compares: self.compares - start.compares,
            bram_reads: self.bram_reads - start.bram_reads,
            prng_steps: self.prng_steps - start.prng_steps,
            reg_toggles: self.reg_toggles - start.reg_toggles,
            cycles: self.cycles - start.cycles,
            saturations: self.saturations - start.saturations,
        }
    }
}

/// Per-op energy constants in picojoules (see module docs for provenance).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    pub pj_add: f64,
    pub pj_shift: f64,
    pub pj_compare: f64,
    pub pj_bram_read: f64,
    pub pj_prng_step: f64,
    pub pj_reg_toggle: f64,
    /// Static + clock-tree power in milliwatts, charged per cycle at
    /// `f_clk` (kept small: the design's idle power floor).
    pub mw_static: f64,
    /// Clock frequency in Hz (paper: 40 MHz).
    pub f_clk_hz: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            pj_add: 0.075,
            pj_shift: 0.024,
            pj_compare: 0.030,
            pj_bram_read: 2.5,
            pj_prng_step: 0.060,
            pj_reg_toggle: 0.0005,
            mw_static: 1.0,
            f_clk_hz: 40.0e6,
        }
    }
}

/// An evaluated energy estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyReport {
    /// Dynamic energy in nanojoules.
    pub dynamic_nj: f64,
    /// Static energy in nanojoules over the counted cycles.
    pub static_nj: f64,
    /// Wall-clock of the counted cycles in microseconds at `f_clk`.
    pub time_us: f64,
    /// Average power in milliwatts.
    pub avg_power_mw: f64,
}

impl EnergyModel {
    /// Convert activity counts into an energy/power estimate.
    pub fn evaluate(&self, act: &ActivityCounters) -> EnergyReport {
        let dynamic_pj = act.adds as f64 * self.pj_add
            + act.shifts as f64 * self.pj_shift
            + act.compares as f64 * self.pj_compare
            + act.bram_reads as f64 * self.pj_bram_read
            + act.prng_steps as f64 * self.pj_prng_step
            + act.reg_toggles as f64 * self.pj_reg_toggle;
        let time_s = act.cycles as f64 / self.f_clk_hz;
        let static_nj = self.mw_static * 1e-3 * time_s * 1e9;
        let dynamic_nj = dynamic_pj * 1e-3;
        let time_us = time_s * 1e6;
        let total_nj = dynamic_nj + static_nj;
        let avg_power_mw = if time_s > 0.0 { total_nj * 1e-9 / time_s * 1e3 } else { 0.0 };
        EnergyReport { dynamic_nj, static_nj, time_us, avg_power_mw }
    }

    /// Per-layer energy reports from per-layer activity windows (the
    /// N-layer core attributes every datapath event and clock to the
    /// layer whose walk produced it; this converts each bucket under the
    /// same constants as the whole-window [`EnergyModel::evaluate`]).
    pub fn evaluate_layers(&self, layers: &[ActivityCounters]) -> Vec<EnergyReport> {
        layers.iter().map(|a| self.evaluate(a)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_activity_zero_dynamic() {
        let m = EnergyModel::default();
        let r = m.evaluate(&ActivityCounters::default());
        assert_eq!(r.dynamic_nj, 0.0);
        assert_eq!(r.static_nj, 0.0);
        assert_eq!(r.time_us, 0.0);
    }

    #[test]
    fn energy_scales_linearly_with_activity() {
        let m = EnergyModel::default();
        let a1 = ActivityCounters { adds: 1000, cycles: 100, ..Default::default() };
        let a2 = ActivityCounters { adds: 2000, cycles: 200, ..Default::default() };
        let r1 = m.evaluate(&a1);
        let r2 = m.evaluate(&a2);
        assert!((r2.dynamic_nj - 2.0 * r1.dynamic_nj).abs() < 1e-12);
        assert!((r2.static_nj - 2.0 * r1.static_nj).abs() < 1e-12);
    }

    #[test]
    fn since_inverts_add() {
        let start = ActivityCounters { adds: 3, cycles: 9, reg_toggles: 2, ..Default::default() };
        let mut total = start;
        let window = ActivityCounters {
            adds: 10,
            shifts: 20,
            compares: 30,
            bram_reads: 5,
            prng_steps: 6,
            reg_toggles: 7,
            cycles: 8,
            saturations: 9,
        };
        total.add(&window);
        assert_eq!(total.since(&start), window);
    }

    #[test]
    fn aggregation_sums_all_fields() {
        let mut a = ActivityCounters { adds: 1, shifts: 2, compares: 3, ..Default::default() };
        let b = ActivityCounters {
            adds: 10,
            shifts: 20,
            compares: 30,
            bram_reads: 5,
            prng_steps: 6,
            reg_toggles: 7,
            cycles: 8,
            saturations: 9,
        };
        a.add(&b);
        assert_eq!(a.adds, 11);
        assert_eq!(a.shifts, 22);
        assert_eq!(a.compares, 33);
        assert_eq!(a.bram_reads, 5);
        assert_eq!(a.prng_steps, 6);
        assert_eq!(a.reg_toggles, 7);
        assert_eq!(a.cycles, 8);
        assert_eq!(a.saturations, 9);
    }

    #[test]
    fn per_layer_reports_decompose_the_total() {
        let m = EnergyModel::default();
        let l0 = ActivityCounters { adds: 1000, bram_reads: 40, cycles: 786, ..Default::default() };
        let l1 = ActivityCounters { adds: 50, bram_reads: 8, cycles: 18, ..Default::default() };
        let reports = m.evaluate_layers(&[l0, l1]);
        assert_eq!(reports.len(), 2);
        let mut total = l0;
        total.add(&l1);
        let whole = m.evaluate(&total);
        let dyn_sum: f64 = reports.iter().map(|r| r.dynamic_nj).sum();
        let static_sum: f64 = reports.iter().map(|r| r.static_nj).sum();
        assert!((dyn_sum - whole.dynamic_nj).abs() < 1e-9);
        assert!((static_sum - whole.static_nj).abs() < 1e-9);
    }

    #[test]
    fn paper_timescale_sanity() {
        // One timestep ≈ 786 cycles at 40 MHz ≈ 19.7 µs; ten timesteps
        // ≈ 197 µs — the measured counterpart of the paper's latency text.
        let m = EnergyModel::default();
        let act = ActivityCounters { cycles: 7860, ..Default::default() };
        let r = m.evaluate(&act);
        assert!((r.time_us - 196.5).abs() < 0.1, "time {}", r.time_us);
    }
}
