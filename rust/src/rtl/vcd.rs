//! Minimal VCD (Value Change Dump) writer for core waveforms.
//!
//! Emits a standard IEEE 1364 VCD header plus value changes for the FSM
//! state, per-neuron membrane potentials, the spike register and the
//! enable lines — enough to eyeball the Fig. 4 dynamics in GTKWave. Only
//! changed signals are dumped per cycle, as the format intends.

use std::fmt::Write as _;

use super::controller::CtrlState;

/// Identifier characters for VCD signals (printable ASCII range).
fn id_char(i: usize) -> char {
    (b'!' + i as u8) as char
}

/// A buffered VCD writer; call [`VcdWriter::finish`] to obtain the text.
#[derive(Debug, Clone)]
pub struct VcdWriter {
    out: String,
    n_neurons: usize,
    last_state: Option<u8>,
    last_membranes: Vec<Option<i32>>,
    last_spikes: Vec<Option<bool>>,
    last_enables: Vec<Option<bool>>,
}

impl VcdWriter {
    /// Create a writer for a core with `n_neurons` outputs. `timescale_ns`
    /// is the clock period annotation (25 ns for the paper's 40 MHz).
    pub fn new(n_neurons: usize, timescale_ns: u32) -> Self {
        let mut out = String::new();
        let _ = writeln!(out, "$date snn-rtl simulation $end");
        let _ = writeln!(out, "$version snn-rtl 0.1.0 $end");
        let _ = writeln!(out, "$timescale {timescale_ns}ns $end");
        let _ = writeln!(out, "$scope module snn_core $end");
        let _ = writeln!(out, "$var wire 3 {} fsm_state $end", id_char(0));
        for j in 0..n_neurons {
            let _ = writeln!(out, "$var wire 32 {} membrane_{j} $end", id_char(1 + j));
        }
        for j in 0..n_neurons {
            let _ = writeln!(out, "$var wire 1 {} spike_{j} $end", id_char(1 + n_neurons + j));
        }
        for j in 0..n_neurons {
            let _ = writeln!(out, "$var wire 1 {} en_{j} $end", id_char(1 + 2 * n_neurons + j));
        }
        let _ = writeln!(out, "$upscope $end");
        let _ = writeln!(out, "$enddefinitions $end");
        VcdWriter {
            out,
            n_neurons,
            last_state: None,
            last_membranes: vec![None; n_neurons],
            last_spikes: vec![None; n_neurons],
            last_enables: vec![None; n_neurons],
        }
    }

    fn state_code(s: &CtrlState) -> u8 {
        match s {
            CtrlState::Idle => 0,
            CtrlState::Integrate { .. } => 1,
            CtrlState::Leak { .. } => 2,
            CtrlState::Fire { .. } => 3,
            CtrlState::Done => 4,
        }
    }

    /// Record one clock's signal values (only changes are written).
    pub fn sample(
        &mut self,
        cycle: u64,
        state: &CtrlState,
        membranes: &[i32],
        spikes: &[bool],
        enables: &[bool],
    ) {
        assert_eq!(membranes.len(), self.n_neurons);
        let mut changes = String::new();
        let code = Self::state_code(state);
        if self.last_state != Some(code) {
            let _ = writeln!(changes, "b{:03b} {}", code, id_char(0));
            self.last_state = Some(code);
        }
        for (j, &m) in membranes.iter().enumerate() {
            if self.last_membranes[j] != Some(m) {
                let _ = writeln!(changes, "b{:b} {}", m as u32, id_char(1 + j));
                self.last_membranes[j] = Some(m);
            }
        }
        for (j, &s) in spikes.iter().enumerate() {
            if self.last_spikes[j] != Some(s) {
                let _ = writeln!(changes, "{}{}", u8::from(s), id_char(1 + self.n_neurons + j));
                self.last_spikes[j] = Some(s);
            }
        }
        for (j, &e) in enables.iter().enumerate() {
            if self.last_enables[j] != Some(e) {
                let _ =
                    writeln!(changes, "{}{}", u8::from(e), id_char(1 + 2 * self.n_neurons + j));
                self.last_enables[j] = Some(e);
            }
        }
        if !changes.is_empty() {
            let _ = writeln!(self.out, "#{cycle}");
            self.out.push_str(&changes);
        }
    }

    /// Finish and return the VCD text.
    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_declares_all_signals() {
        let v = VcdWriter::new(10, 25).finish();
        assert!(v.contains("$timescale 25ns $end"));
        assert!(v.contains("fsm_state"));
        for j in 0..10 {
            assert!(v.contains(&format!("membrane_{j}")));
            assert!(v.contains(&format!("spike_{j}")));
            assert!(v.contains(&format!("en_{j}")));
        }
        assert!(v.contains("$enddefinitions $end"));
    }

    #[test]
    fn only_changes_are_dumped() {
        let mut v = VcdWriter::new(2, 25);
        let st = CtrlState::Integrate { layer: 0, pixel: 0 };
        v.sample(1, &st, &[0, 0], &[false, false], &[true, true]);
        let after_first = v.out.len();
        // Identical sample: nothing new may be written.
        v.sample(2, &st, &[0, 0], &[false, false], &[true, true]);
        assert_eq!(v.out.len(), after_first);
        // One membrane change: exactly one timestamped delta.
        v.sample(3, &st, &[5, 0], &[false, false], &[true, true]);
        let text = v.finish();
        assert!(text.contains("#3"));
        assert!(text.contains("b101 \""));
    }

    #[test]
    fn full_run_produces_parseable_dump() {
        use crate::config::SnnConfig;
        use crate::data::DigitGen;
        use crate::fixed::WeightMatrix;
        use crate::rtl::RtlCore;

        let cfg = SnnConfig::paper().with_timesteps(2);
        let w = WeightMatrix::from_rows(784, 10, 9, vec![10; 7840]).unwrap();
        let mut core = RtlCore::new(cfg, w).unwrap();
        core.attach_vcd(VcdWriter::new(10, 25));
        let img = DigitGen::new(1).sample(4, 0);
        core.run(&img, 77).unwrap();
        let vcd = core.detach_vcd().unwrap().finish();
        // Sanity: header + at least one change block per FSM transition.
        assert!(vcd.matches('#').count() > 10);
        assert!(vcd.lines().all(|l| !l.trim().is_empty()));
    }
}
