//! Parser for `artifacts/manifest.txt` (key=value lines written by
//! `python/compile/aot.py`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::config::{LayerParams, PruneMode, SnnConfig};
use crate::error::{Error, Result};

/// Parsed artifact manifest: the build-time configuration every runtime
/// component cross-checks against.
#[derive(Debug, Clone)]
pub struct Manifest {
    kv: HashMap<String, String>,
    /// Directory the manifest was loaded from (artifact paths resolve
    /// relative to it).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| Error::io(&path, e))?;
        let mut kv = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(Error::malformed(
                    &path,
                    format!("line {}: expected key=value, got {line:?}", lineno + 1),
                ));
            };
            kv.insert(k.to_string(), v.to_string());
        }
        let m = Manifest { kv, dir };
        // Schema check + required keys early, so failures are immediate.
        if m.u32("schema")? != 1 {
            return Err(Error::malformed(path, "unsupported manifest schema"));
        }
        Ok(m)
    }

    /// Raw string value.
    pub fn get(&self, key: &str) -> Result<&str> {
        self.kv
            .get(key)
            .map(String::as_str)
            .ok_or_else(|| Error::malformed(self.dir.join("manifest.txt"), format!("missing key {key}")))
    }

    /// Parse a u32 value.
    pub fn u32(&self, key: &str) -> Result<u32> {
        self.get(key)?.parse().map_err(|e| {
            Error::malformed(self.dir.join("manifest.txt"), format!("key {key}: {e}"))
        })
    }

    /// Parse an i32 value.
    pub fn i32(&self, key: &str) -> Result<i32> {
        self.get(key)?.parse().map_err(|e| {
            Error::malformed(self.dir.join("manifest.txt"), format!("key {key}: {e}"))
        })
    }

    /// Parse an f64 value (accuracy stats).
    pub fn f64(&self, key: &str) -> Result<f64> {
        self.get(key)?.parse().map_err(|e| {
            Error::malformed(self.dir.join("manifest.txt"), format!("key {key}: {e}"))
        })
    }

    /// Comma-separated u32 list (batch size sets).
    pub fn u32_list(&self, key: &str) -> Result<Vec<u32>> {
        self.get(key)?
            .split(',')
            .map(|s| {
                s.trim().parse().map_err(|e| {
                    Error::malformed(self.dir.join("manifest.txt"), format!("key {key}: {e}"))
                })
            })
            .collect()
    }

    /// The layer dimension chain of the artifacts. Multi-layer manifests
    /// carry an explicit `topology=784,128,10` key; legacy manifests only
    /// have the scalar `n_inputs`/`n_outputs` pair, which maps to the
    /// single-layer chain.
    pub fn topology(&self) -> Result<Vec<usize>> {
        if self.kv.contains_key("topology") {
            let dims: Vec<usize> =
                self.u32_list("topology")?.into_iter().map(|d| d as usize).collect();
            if dims.len() < 2 || dims.contains(&0) {
                return Err(Error::malformed(
                    self.dir.join("manifest.txt"),
                    format!("topology {dims:?} needs >= 2 nonzero dims"),
                ));
            }
            return Ok(dims);
        }
        Ok(vec![self.u32("n_inputs")? as usize, self.u32("n_outputs")? as usize])
    }

    /// Optional per-layer parameter overrides: the `layer_params=` key
    /// holds one `v_th:decay_shift:prune_after` triple per weight layer,
    /// comma separated (`layer_params=160:3:1,128:2:0`). Any field may be
    /// `-` to inherit the scalar default; `prune_after` follows the
    /// scalar convention (0 = pruning off). Returns an empty list when
    /// the key is absent.
    pub fn layer_params(&self) -> Result<Vec<LayerParams>> {
        let Some(raw) = self.kv.get("layer_params") else {
            return Ok(Vec::new());
        };
        let path = self.dir.join("manifest.txt");
        let mut out = Vec::new();
        for (l, entry) in raw.split(',').enumerate() {
            let fields: Vec<&str> = entry.trim().split(':').collect();
            if fields.len() != 3 {
                return Err(Error::malformed(
                    &path,
                    format!(
                        "layer_params entry {l}: want v_th:decay_shift:prune_after, \
                         got {entry:?}"
                    ),
                ));
            }
            // Each field parses into its exact target width — a wrapping
            // `as` cast would let `-1` or `2^32+1` masquerade as a valid
            // huge/small value instead of the malformed-manifest error
            // every other bad field gets.
            let v_th = match fields[0] {
                "-" => None,
                s => Some(s.parse::<i32>().map_err(|e| {
                    Error::malformed(&path, format!("layer_params entry {l} v_th: {e}"))
                })?),
            };
            let decay_shift = match fields[1] {
                "-" => None,
                s => Some(s.parse::<u32>().map_err(|e| {
                    Error::malformed(&path, format!("layer_params entry {l} decay_shift: {e}"))
                })?),
            };
            let prune = match fields[2] {
                "-" => None,
                s => {
                    let after = s.parse::<u32>().map_err(|e| {
                        Error::malformed(
                            &path,
                            format!("layer_params entry {l} prune_after: {e}"),
                        )
                    })?;
                    Some(if after == 0 {
                        PruneMode::Off
                    } else {
                        PruneMode::AfterFires { after_spikes: after }
                    })
                }
            };
            out.push(LayerParams { v_th, decay_shift, prune });
        }
        Ok(out)
    }

    /// The SnnConfig the artifacts were built for.
    pub fn snn_config(&self) -> Result<SnnConfig> {
        let prune_after = self.u32("prune_after")?;
        SnnConfig {
            topology: self.topology()?,
            v_th: self.i32("v_th")?,
            v_rest: self.i32("v_rest")?,
            decay_shift: self.u32("decay_shift")?,
            acc_bits: self.u32("acc_bits")?,
            weight_bits: self.u32("weight_bits")?,
            timesteps: self.u32("timesteps")?,
            prune: if prune_after == 0 {
                PruneMode::Off
            } else {
                PruneMode::AfterFires { after_spikes: prune_after }
            },
            layer_params: self.layer_params()?,
            ..SnnConfig::paper()
        }
        .validated()
    }

    /// Resolve an artifact file path.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// The shared eval-seed convention (`seed_i = base + i·mult`), mirrored
    /// from `python/compile/aot.py`.
    pub fn eval_seed(&self, index: u32) -> Result<u32> {
        let base = self.u32("eval_seed_base")?;
        let mult = self.u32("eval_seed_mult")?;
        Ok(base.wrapping_add(index.wrapping_mul(mult)))
    }

    /// Optional sparse serving calibration: the magnitude-pruning
    /// threshold the export pipeline applied when writing the SNNW v4
    /// sparse section (`sparse_threshold=` key; absent = dense-only
    /// artifact). Never negative.
    pub fn sparse_threshold(&self) -> Result<Option<i32>> {
        if !self.kv.contains_key("sparse_threshold") {
            return Ok(None);
        }
        let t = self.i32("sparse_threshold")?;
        if t < 0 {
            return Err(Error::malformed(
                self.dir.join("manifest.txt"),
                format!("sparse_threshold {t} < 0"),
            ));
        }
        Ok(Some(t))
    }

    /// Optional recorded CSR density (`nnz / total` at
    /// `sparse_threshold`, in [0, 1]) — advisory: lets backend selection
    /// pick the sparse engine without re-deriving the CSR image.
    pub fn sparse_density(&self) -> Result<Option<f64>> {
        if !self.kv.contains_key("sparse_density") {
            return Ok(None);
        }
        let d = self.f64("sparse_density")?;
        if !(0.0..=1.0).contains(&d) {
            return Err(Error::malformed(
                self.dir.join("manifest.txt"),
                format!("sparse_density {d} outside [0, 1]"),
            ));
        }
        Ok(Some(d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn write_manifest(dir: &Path, body: &str) {
        fs::create_dir_all(dir).unwrap();
        fs::write(dir.join("manifest.txt"), body).unwrap();
    }

    fn full_body() -> &'static str {
        "schema=1\nn_inputs=784\nn_outputs=10\nv_th=384\nv_rest=0\n\
         decay_shift=3\nacc_bits=24\nweight_bits=9\ntimesteps=20\n\
         prune_after=5\neval_seed_base=12648430\neval_seed_mult=2654435761\n\
         forward_batches=1,8,32\n"
    }

    #[test]
    fn parses_full_manifest() {
        let dir = std::env::temp_dir().join(format!("snn_manifest_{}", std::process::id()));
        write_manifest(&dir, full_body());
        let m = Manifest::load(&dir).unwrap();
        let cfg = m.snn_config().unwrap();
        assert_eq!(cfg.v_th, 384);
        assert_eq!(cfg.topology, vec![784, 10], "legacy scalar pair maps to one layer");
        assert_eq!(cfg.prune, PruneMode::AfterFires { after_spikes: 5 });
        assert_eq!(m.u32_list("forward_batches").unwrap(), vec![1, 8, 32]);
        assert_eq!(m.eval_seed(0).unwrap(), 12648430);
        assert_eq!(m.eval_seed(1).unwrap(), 12648430u32.wrapping_add(2654435761));
    }

    #[test]
    fn topology_key_overrides_scalar_pair() {
        let dir = std::env::temp_dir().join(format!("snn_manifest_topo_{}", std::process::id()));
        write_manifest(&dir, &format!("{}topology=784,128,10\n", full_body()));
        let m = Manifest::load(&dir).unwrap();
        let cfg = m.snn_config().unwrap();
        assert_eq!(cfg.topology, vec![784, 128, 10]);
        assert_eq!(cfg.n_layers(), 2);
        // Degenerate chains are rejected.
        write_manifest(&dir, &format!("{}topology=784\n", full_body()));
        assert!(Manifest::load(&dir).unwrap().snn_config().is_err());
        write_manifest(&dir, &format!("{}topology=784,0,10\n", full_body()));
        assert!(Manifest::load(&dir).unwrap().snn_config().is_err());
    }

    #[test]
    fn layer_params_key_parses_and_validates() {
        let dir = std::env::temp_dir().join(format!("snn_manifest_lp_{}", std::process::id()));
        write_manifest(
            &dir,
            &format!("{}topology=784,128,10\nlayer_params=160:-:1,40:2:0\n", full_body()),
        );
        let m = Manifest::load(&dir).unwrap();
        let cfg = m.snn_config().unwrap();
        assert_eq!(cfg.layer_v_th(0), 160);
        assert_eq!(cfg.layer_decay_shift(0), 3, "`-` inherits the scalar decay");
        assert_eq!(cfg.layer_prune(0), PruneMode::AfterFires { after_spikes: 1 });
        assert_eq!(cfg.layer_v_th(1), 40);
        assert_eq!(cfg.layer_decay_shift(1), 2);
        assert_eq!(cfg.layer_prune(1), PruneMode::Off);
        // Arity mismatch against the topology is rejected by validation.
        write_manifest(
            &dir,
            &format!("{}topology=784,128,10\nlayer_params=160:3:1\n", full_body()),
        );
        assert!(Manifest::load(&dir).unwrap().snn_config().is_err());
        // Malformed entries are rejected at parse.
        write_manifest(&dir, &format!("{}layer_params=160:3\n", full_body()));
        assert!(Manifest::load(&dir).unwrap().snn_config().is_err());
        write_manifest(&dir, &format!("{}layer_params=abc:3:1\n", full_body()));
        assert!(Manifest::load(&dir).unwrap().snn_config().is_err());
        // Out-of-width values must be malformed, not silently wrapped.
        write_manifest(&dir, &format!("{}layer_params=160:3:-1\n", full_body()));
        assert!(Manifest::load(&dir).unwrap().snn_config().is_err());
        write_manifest(&dir, &format!("{}layer_params=4294967297:3:1\n", full_body()));
        assert!(Manifest::load(&dir).unwrap().snn_config().is_err());
        // Absent key → empty overrides (the shared-parameter default).
        write_manifest(&dir, full_body());
        assert!(Manifest::load(&dir).unwrap().snn_config().unwrap().layer_params.is_empty());
    }

    #[test]
    fn sparse_keys_parse_and_validate() {
        let dir = std::env::temp_dir().join(format!("snn_manifest_sp_{}", std::process::id()));
        // Absent keys → None (every pre-sparse manifest stays valid).
        write_manifest(&dir, full_body());
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.sparse_threshold().unwrap(), None);
        assert_eq!(m.sparse_density().unwrap(), None);
        // Present keys parse.
        write_manifest(
            &dir,
            &format!("{}sparse_threshold=12\nsparse_density=0.085\n", full_body()),
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.sparse_threshold().unwrap(), Some(12));
        assert_eq!(m.sparse_density().unwrap(), Some(0.085));
        // Out-of-range values are malformed, not clamped.
        write_manifest(&dir, &format!("{}sparse_threshold=-3\n", full_body()));
        assert!(Manifest::load(&dir).unwrap().sparse_threshold().is_err());
        write_manifest(&dir, &format!("{}sparse_density=1.5\n", full_body()));
        assert!(Manifest::load(&dir).unwrap().sparse_density().is_err());
    }

    #[test]
    fn rejects_bad_schema_and_lines() {
        let dir = std::env::temp_dir().join(format!("snn_manifest_bad_{}", std::process::id()));
        write_manifest(&dir, "schema=2\n");
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, "schema=1\nnot a kv line\n");
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, "schema=1\n");
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("v_th").is_err());
        assert!(m.snn_config().is_err());
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            let cfg = m.snn_config().unwrap();
            assert_eq!(cfg.n_inputs(), 784);
            assert_eq!(cfg.n_outputs(), 10);
        }
    }
}
