//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them from
//! the Rust request path.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6, PJRT C API): HLO *text* from
//! `artifacts/*.hlo.txt` is parsed into an `HloModuleProto`, compiled once
//! per model variant by the CPU PJRT client, and executed with concrete
//! `Literal` inputs. Text is the interchange format because jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects (see /opt/xla-example/README.md).
//!
//! The `xla` crate is not part of the offline crate set, so the real
//! backend only compiles under the off-by-default `xla` cargo feature; the
//! default build substitutes an API-compatible stub whose `load` fails
//! cleanly (every caller already handles artifacts being unavailable).

mod manifest;

#[cfg(feature = "xla")]
mod xla_backend;
#[cfg(not(feature = "xla"))]
#[path = "xla_stub.rs"]
mod xla_backend;

pub use manifest::Manifest;
pub use xla_backend::{SnnChunkState, XlaSnn};
