//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them from
//! the Rust request path.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6, PJRT C API): HLO *text* from
//! `artifacts/*.hlo.txt` is parsed into an `HloModuleProto`, compiled once
//! per model variant by the CPU PJRT client, and executed with concrete
//! `Literal` inputs. Text is the interchange format because jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects (see /opt/xla-example/README.md).

mod manifest;
mod xla_backend;

pub use manifest::Manifest;
pub use xla_backend::XlaSnn;
