//! The compiled-HLO inference backend (the L2/L1 stack running under rust).
//!
//! `XlaSnn` owns a PJRT CPU client plus one compiled executable per
//! artifact: full-window forwards at several batch sizes, the chunked
//! forward used by the early-exit scheduler, and the baseline ANN. Weights
//! are marshalled to a `Literal` once at load time and cloned per call
//! (cheap host copy; the compile stays cached).

use std::collections::BTreeMap;
use std::path::Path;

use crate::data::{codec, Image, WeightArtifact};
use crate::error::{Error, Result};
use crate::prng::{pixel_seed, xorshift32_step};
use crate::SnnConfig;

use super::manifest::Manifest;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Convert raw little-endian data into a Literal of the given shape.
fn literal(ty: xla::ElementType, dims: &[usize], bytes: &[u8]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes).map_err(Error::from)
}

fn literal_i32(dims: &[usize], vals: &[i32]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    literal(xla::ElementType::S32, dims, &bytes)
}

fn literal_u32(dims: &[usize], vals: &[u32]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    literal(xla::ElementType::U32, dims, &bytes)
}

fn literal_f32(dims: &[usize], vals: &[f32]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    literal(xla::ElementType::F32, dims, &bytes)
}

/// In-flight state of a chunked (early-exit) batch on the XLA backend.
///
/// The carry is the PACKED single int32 array produced by the untupled
/// chunk executable (`python/compile/model.py::pack_carry` layout:
/// `[xorshift states (P) | acc (N) | counts (N) | enabled (N)]` along
/// axis 1). It lives as a device-resident `PjRtBuffer` between chunks —
/// the executable's output buffer is fed straight back in as the next
/// input (perf pass 6); one host copy per chunk extracts the counts for
/// the margin check.
pub struct SnnChunkState {
    images: xla::PjRtBuffer,
    carry: xla::PjRtBuffer,
    /// Timesteps executed so far.
    pub steps_run: u32,
    /// Logical batch occupancy (rows beyond this are padding).
    pub occupancy: usize,
}

/// The PJRT-backed SNN + baseline ANN.
pub struct XlaSnn {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    /// Full-window forward executables keyed by batch size.
    forwards: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    chunk: xla::PjRtLoadedExecutable,
    chunk_init: xla::PjRtLoadedExecutable,
    chunk_batch: usize,
    chunk_steps: u32,
    ann: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    weights_lit: xla::Literal,
    /// Device-resident copy of the weights for the chunked (execute_b)
    /// path — uploaded once at load.
    weights_buf: xla::PjRtBuffer,
    ann_params: Option<[xla::Literal; 4]>,
    cfg: SnnConfig,
    pub manifest: Manifest,
}

// SAFETY: `XlaSnn` owns its PJRT client, executables and literals
// exclusively — the internal `Rc` clones (client handles held by each
// executable) and raw C pointers never escape the struct, so moving the
// whole value to another thread moves every aliased handle together.
// Shared *concurrent* use is NOT claimed (no `Sync`); the coordinator's
// `XlaBackend` serializes access behind a `Mutex`.
unsafe impl Send for XlaSnn {}

impl XlaSnn {
    /// Load every executable described by `<artifacts>/manifest.txt`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let cfg = manifest.snn_config()?;
        let weights = codec::load_weights(manifest.path("weights.bin"))?;
        Self::check_calibration(&cfg, &weights)?;

        let client = xla::PjRtClient::cpu()?;
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.path(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Xla("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };

        let mut forwards = BTreeMap::new();
        for b in manifest.u32_list("forward_batches")? {
            forwards.insert(b as usize, compile(&format!("snn_forward_b{b}.hlo.txt"))?);
        }
        let chunk_batch = 8usize;
        let chunk = compile(&format!("snn_chunk_b{chunk_batch}.hlo.txt"))?;
        let chunk_init = compile(&format!("snn_init_b{chunk_batch}.hlo.txt"))?;
        let chunk_steps = manifest.u32("chunk_steps")?;

        let mut ann = BTreeMap::new();
        for b in manifest.u32_list("ann_batches")? {
            ann.insert(b as usize, compile(&format!("ann_mlp_b{b}.hlo.txt"))?);
        }
        let ann_params = match codec_load_ann(&manifest.path("ann_weights.bin")) {
            Ok(p) => Some(p),
            Err(_) => None, // ANN artifact optional for SNN-only deployments
        };

        let weights_lit = literal_i32(
            &[cfg.n_inputs(), cfg.n_outputs()],
            weights.weights.as_slice(),
        )?;
        // Synchronous-copy upload (see the note in `chunk_start` about the
        // async hazard of buffer_from_host_literal).
        let weights_buf = client.buffer_from_host_buffer(
            weights.weights.as_slice(),
            &[cfg.n_inputs(), cfg.n_outputs()],
            None,
        )?;

        Ok(XlaSnn {
            client,
            forwards,
            chunk,
            chunk_init,
            chunk_batch,
            chunk_steps,
            ann,
            weights_lit,
            weights_buf,
            ann_params,
            cfg,
            manifest,
        })
    }

    fn check_calibration(cfg: &SnnConfig, w: &WeightArtifact) -> Result<()> {
        // The compiled HLO graphs implement the single-FC-layer forward;
        // a deep manifest must be rejected here, not silently served with
        // single-layer dynamics.
        if cfg.n_layers() != 1 {
            return Err(Error::InvalidConfig(format!(
                "the XLA backend's compiled executables are single-layer; manifest \
                 topology {:?} needs the behavioral or rtl backend",
                cfg.topology
            )));
        }
        // The HLO graphs bake the scalar calibration in at compile time;
        // per-layer overrides cannot reach them. Reject rather than serve
        // dynamics that diverge from the behavioral/RTL backends.
        if !cfg.layer_params.is_empty() {
            return Err(Error::InvalidConfig(
                "manifest carries layer_params overrides, which the compiled XLA \
                 executables cannot apply; use the behavioral or rtl backend (or \
                 rebuild artifacts without per-layer overrides)"
                    .into(),
            ));
        }
        let wc = w.config();
        if wc.v_th != cfg.v_th
            || wc.decay_shift != cfg.decay_shift
            || wc.prune != cfg.prune
            || wc.topology != cfg.topology
        {
            return Err(Error::InvalidConfig(format!(
                "weights calibration {wc:?} disagrees with manifest config {cfg:?} — \
                 rebuild artifacts (`make clean-artifacts && make artifacts`)"
            )));
        }
        Ok(())
    }

    /// The architectural config baked into the executables.
    pub fn config(&self) -> &SnnConfig {
        &self.cfg
    }

    /// Compiled forward batch sizes, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.forwards.keys().copied().collect()
    }

    /// Chunk granularity of the early-exit path (timesteps per chunk).
    pub fn chunk_steps(&self) -> u32 {
        self.chunk_steps
    }

    /// Batch capacity of the chunked executable.
    pub fn chunk_batch(&self) -> usize {
        self.chunk_batch
    }

    /// Classify a batch over the full compiled window; returns per-image
    /// spike counts. Picks the smallest compiled batch ≥ `images.len()`
    /// (padding with zeros) or splits across the largest.
    pub fn spike_counts(&self, images: &[&Image], seeds: &[u32]) -> Result<Vec<Vec<u32>>> {
        if images.len() != seeds.len() {
            return Err(Error::ShapeMismatch(format!(
                "{} images vs {} seeds",
                images.len(),
                seeds.len()
            )));
        }
        let mut out = Vec::with_capacity(images.len());
        let max_b = *self.forwards.keys().last().expect("at least one forward");
        let mut i = 0usize;
        while i < images.len() {
            let remaining = images.len() - i;
            let b = self
                .forwards
                .keys()
                .copied()
                .find(|&b| b >= remaining)
                .unwrap_or(max_b);
            let take = remaining.min(b);
            out.extend(self.forward_padded(&images[i..i + take], &seeds[i..i + take], b)?);
            i += take;
        }
        Ok(out)
    }

    fn forward_padded(
        &self,
        images: &[&Image],
        seeds: &[u32],
        b: usize,
    ) -> Result<Vec<Vec<u32>>> {
        let exe = &self.forwards[&b];
        let p = self.cfg.n_inputs();
        let n = self.cfg.n_outputs();
        let mut img_flat = vec![0i32; b * p];
        for (row, img) in images.iter().enumerate() {
            for (k, &px) in img.pixels.iter().enumerate() {
                img_flat[row * p + k] = i32::from(px);
            }
        }
        let mut seed_flat = vec![1u32; b];
        seed_flat[..seeds.len()].copy_from_slice(seeds);

        let args = [
            literal_i32(&[b, p], &img_flat)?,
            literal_u32(&[b], &seed_flat)?,
            self.weights_lit.clone(),
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let counts_lit = result.to_tuple1()?;
        let flat = counts_lit.to_vec::<i32>()?;
        Ok((0..images.len())
            .map(|row| flat[row * n..(row + 1) * n].iter().map(|&c| c as u32).collect())
            .collect())
    }

    /// Start a chunked inference for up to [`Self::chunk_batch`] images.
    pub fn chunk_start(&self, images: &[&Image], seeds: &[u32]) -> Result<SnnChunkState> {
        let b = self.chunk_batch;
        if images.len() > b || images.len() != seeds.len() {
            return Err(Error::ShapeMismatch(format!(
                "chunk batch holds {b}, got {} images / {} seeds",
                images.len(),
                seeds.len()
            )));
        }
        let p = self.cfg.n_inputs();
        let mut img_flat = vec![0i32; b * p];
        for (row, img) in images.iter().enumerate() {
            for (k, &px) in img.pixels.iter().enumerate() {
                img_flat[row * p + k] = i32::from(px);
            }
        }
        let mut seed_flat = vec![1u32; b];
        seed_flat[..seeds.len()].copy_from_slice(seeds);

        // The init executable is array-root (untupled): its single result
        // buffer IS the packed carry and stays device-resident.
        let mut init_out = self
            .chunk_init
            .execute::<xla::Literal>(&[literal_u32(&[b], &seed_flat)?])?;
        let mut replica = init_out.swap_remove(0);
        if replica.len() != 1 {
            return Err(Error::Xla(format!(
                "init executable returned {} buffers, expected 1 packed carry",
                replica.len()
            )));
        }
        let carry = replica.swap_remove(0);
        // NOTE: upload via buffer_from_host_buffer, whose
        // kImmutableOnlyDuringCall semantics copy the data synchronously.
        // buffer_from_host_literal schedules an ASYNC copy that the shim
        // never awaits — dropping the source literal then races the
        // transfer (observed as a `literal.size_bytes() == b->size()`
        // CHECK crash under repeated chunk_start load).
        Ok(SnnChunkState {
            images: self.client.buffer_from_host_buffer(&img_flat, &[b, p], None)?,
            carry,
            steps_run: 0,
            occupancy: images.len(),
        })
    }

    /// Advance one chunk (`chunk_steps` timesteps); returns the per-image
    /// spike counts after the chunk. The packed carry never leaves the
    /// device; one host copy extracts the counts slice for the margin
    /// check (perf pass 6).
    pub fn chunk_advance(&self, st: &mut SnnChunkState) -> Result<Vec<Vec<u32>>> {
        let args = [&st.images, &st.carry, &self.weights_buf];
        let mut out = self.chunk.execute_b::<&xla::PjRtBuffer>(&args)?;
        let mut replica = out.swap_remove(0);
        if replica.len() != 1 {
            return Err(Error::Xla(format!(
                "chunk executable returned {} buffers, expected 1 packed carry",
                replica.len()
            )));
        }
        st.carry = replica.swap_remove(0);
        st.steps_run += self.chunk_steps;

        // Packed layout: [states(P) | acc(N) | counts(N) | enabled(N)].
        let p = self.cfg.n_inputs();
        let n = self.cfg.n_outputs();
        let stride = p + 3 * n;
        let flat = st.carry.to_literal_sync()?.to_vec::<i32>()?;
        Ok((0..st.occupancy)
            .map(|row| {
                let base = row * stride + p + n;
                flat[base..base + n].iter().map(|&c| c as u32).collect()
            })
            .collect())
    }

    /// Baseline ANN logits for a batch (paper §V comparator).
    pub fn ann_logits(&self, images: &[&Image]) -> Result<Vec<Vec<f32>>> {
        let params = self
            .ann_params
            .as_ref()
            .ok_or_else(|| Error::InvalidConfig("ann_weights.bin not built".into()))?;
        let max_b = *self.ann.keys().last().expect("ann exe");
        let p = self.cfg.n_inputs();
        let n = self.cfg.n_outputs();
        let mut out = Vec::with_capacity(images.len());
        let mut i = 0;
        while i < images.len() {
            let remaining = images.len() - i;
            let b = self.ann.keys().copied().find(|&b| b >= remaining).unwrap_or(max_b);
            let take = remaining.min(b);
            let mut flat = vec![0f32; b * p];
            for (row, img) in images[i..i + take].iter().enumerate() {
                for (k, &px) in img.pixels.iter().enumerate() {
                    flat[row * p + k] = f32::from(px) / 256.0;
                }
            }
            let args = [
                literal_f32(&[b, p], &flat)?,
                params[0].clone(),
                params[1].clone(),
                params[2].clone(),
                params[3].clone(),
            ];
            let result = self.ann[&b].execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let logits = result.to_tuple1()?.to_vec::<f32>()?;
            for row in 0..take {
                out.push(logits[row * n..(row + 1) * n].to_vec());
            }
            i += take;
        }
        Ok(out)
    }

    /// Reference seeding helper exposed for tests (matches the pixel_seed
    /// contract the executables bake in).
    pub fn debug_first_state(&self, seed: u32) -> u32 {
        xorshift32_step(pixel_seed(seed, 0))
    }
}

/// Load the SNNA baseline-ANN weights as literals.
fn codec_load_ann(path: &Path) -> Result<[xla::Literal; 4]> {
    let buf = std::fs::read(path).map_err(|e| Error::io(path, e))?;
    if buf.len() < 20 || &buf[..4] != b"SNNA" {
        return Err(Error::malformed(path, "bad magic (want SNNA)"));
    }
    let rd_u32 =
        |at: usize| u32::from_le_bytes(buf[at..at + 4].try_into().unwrap()) as usize;
    let version = rd_u32(4);
    if version != 1 {
        return Err(Error::malformed(path, format!("unsupported version {version}")));
    }
    let (n_in, n_h, n_out) = (rd_u32(8), rd_u32(12), rd_u32(16));
    let need = 20 + 4 * (n_in * n_h + n_h + n_h * n_out + n_out);
    if buf.len() != need {
        return Err(Error::malformed(path, format!("size {} != expected {need}", buf.len())));
    }
    let mut pos = 20usize;
    let mut take = |dims: &[usize]| -> Result<xla::Literal> {
        let count: usize = dims.iter().product();
        let lit = literal(xla::ElementType::F32, dims, &buf[pos..pos + count * 4])?;
        pos += count * 4;
        Ok(lit)
    };
    Ok([
        take(&[n_in, n_h])?,
        take(&[n_h])?,
        take(&[n_h, n_out])?,
        take(&[n_out])?,
    ])
}

#[cfg(test)]
mod tests {
    //! Unit tests that don't need built artifacts live here; the live
    //! PJRT round-trip tests are in `rust/tests/xla_runtime.rs` (they
    //! require `make artifacts` to have run).
    use super::*;

    #[test]
    fn literal_helpers_roundtrip() {
        let l = literal_i32(&[2, 3], &[1, -2, 3, -4, 5, -6]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, -2, 3, -4, 5, -6]);
        let l = literal_u32(&[4], &[1, 2, 3, 0xFFFF_FFFF]).unwrap();
        assert_eq!(l.to_vec::<u32>().unwrap(), vec![1, 2, 3, 0xFFFF_FFFF]);
        let l = literal_f32(&[2], &[1.5, -2.5]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.5, -2.5]);
    }

    #[test]
    fn literal_rejects_wrong_byte_count() {
        assert!(literal(xla::ElementType::S32, &[4], &[0u8; 7]).is_err());
    }
}
