//! API-compatible stand-in for [`super::xla_backend`] when the crate is
//! built without the `xla` feature (the offline default).
//!
//! [`XlaSnn::load`] always fails with [`Error::Xla`], and the struct is
//! uninhabited (it carries a [`Never`] field), so every other method is
//! statically unreachable — the stub costs nothing and cannot lie about
//! results. Callers already treat "XLA unavailable" as a skippable
//! condition (benches print a notice, tests gate on the artifacts dir,
//! `snn-rtl --backend xla` reports the error).
//!
//! Lock-freedom note (pallas-lint L5): unlike the real backend — which
//! serializes PJRT calls behind the `backend.xla_snn` mutex — this stub
//! holds no `Mutex` and acquires none, so the offline build contributes
//! no `xla` nodes to the declared lock graph.

use std::path::Path;

use crate::data::Image;
use crate::error::{Error, Result};
use crate::SnnConfig;

use super::manifest::Manifest;

/// Uninhabited type: makes the stub structs impossible to construct.
#[derive(Debug, Clone, Copy)]
enum Never {}

/// In-flight state of a chunked (early-exit) batch. Stub: never exists.
pub struct SnnChunkState {
    /// Timesteps executed so far.
    pub steps_run: u32,
    /// Logical batch occupancy (rows beyond this are padding).
    pub occupancy: usize,
    #[allow(dead_code)]
    never: Never,
}

/// The PJRT-backed SNN + baseline ANN. Stub: construction always fails.
pub struct XlaSnn {
    pub manifest: Manifest,
    never: Never,
}

impl XlaSnn {
    /// Always fails: the crate was built without the `xla` feature.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let _ = artifacts_dir;
        Err(Error::Xla(
            "this build has no PJRT runtime (compiled without the `xla` cargo feature); \
             use the `behavioral` or `rtl` backend"
                .into(),
        ))
    }

    /// The architectural config baked into the executables.
    pub fn config(&self) -> &SnnConfig {
        match self.never {}
    }

    /// Compiled forward batch sizes, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        match self.never {}
    }

    /// Chunk granularity of the early-exit path (timesteps per chunk).
    pub fn chunk_steps(&self) -> u32 {
        match self.never {}
    }

    /// Batch capacity of the chunked executable.
    pub fn chunk_batch(&self) -> usize {
        match self.never {}
    }

    /// Classify a batch over the full compiled window.
    pub fn spike_counts(&self, images: &[&Image], seeds: &[u32]) -> Result<Vec<Vec<u32>>> {
        let _ = (images, seeds);
        match self.never {}
    }

    /// Start a chunked inference.
    pub fn chunk_start(&self, images: &[&Image], seeds: &[u32]) -> Result<SnnChunkState> {
        let _ = (images, seeds);
        match self.never {}
    }

    /// Advance one chunk.
    pub fn chunk_advance(&self, st: &mut SnnChunkState) -> Result<Vec<Vec<u32>>> {
        let _ = st;
        match self.never {}
    }

    /// Baseline ANN logits for a batch.
    pub fn ann_logits(&self, images: &[&Image]) -> Result<Vec<Vec<f32>>> {
        let _ = images;
        match self.never {}
    }

    /// Reference seeding helper exposed for tests.
    pub fn debug_first_state(&self, seed: u32) -> u32 {
        let _ = seed;
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = XlaSnn::load("artifacts").err().expect("stub load must fail");
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
