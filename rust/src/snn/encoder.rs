//! Behavioral Poisson encoder (paper §III-C).
//!
//! One xorshift32 stream per pixel; at every timestep each stream advances
//! once and pixel `i` emits a spike iff `intensity_i > (state_i & 0xFF)`,
//! so the firing rate is `intensity/256` — brighter pixels spike more.
//! Bit-identical to the RTL encoder and to
//! `python/compile/kernels/encoder.py`.

use crate::data::Image;
use crate::prng::StreamBank;

/// Stateful encoder over one image presentation.
#[derive(Debug, Clone)]
pub struct PoissonEncoder {
    bank: StreamBank,
    intensities: Vec<u8>,
}

impl PoissonEncoder {
    /// Start encoding `img` under `seed`. Stream `i` is seeded by the
    /// [`crate::prng::pixel_seed`] contract.
    pub fn new(img: &Image, seed: u32) -> Self {
        PoissonEncoder {
            bank: StreamBank::new(seed, img.pixels.len()),
            intensities: img.pixels.clone(),
        }
    }

    /// Number of input channels.
    pub fn len(&self) -> usize {
        self.intensities.len()
    }

    /// True if the encoder has no channels.
    pub fn is_empty(&self) -> bool {
        self.intensities.is_empty()
    }

    /// Advance one timestep, writing one spike flag per pixel into `out`.
    pub fn step_into(&mut self, out: &mut [bool]) {
        debug_assert_eq!(out.len(), self.intensities.len());
        let states = self.bank.step();
        for ((o, &s), &px) in out.iter_mut().zip(states).zip(&self.intensities) {
            *o = u32::from(px) > (s & 0xFF);
        }
    }

    /// Advance one timestep, allocating the spike vector.
    pub fn step(&mut self) -> Vec<bool> {
        let mut out = vec![false; self.intensities.len()];
        self.step_into(&mut out);
        out
    }

    /// Advance one timestep, appending the *indices* of spiking pixels to
    /// `out` (cleared first). Fuses encoding with the event-list build the
    /// integration loop wants, skipping the boolean buffer round-trip
    /// (perf pass 4; property-tested equal to [`PoissonEncoder::step`]).
    pub fn step_active_into(&mut self, out: &mut Vec<u32>) {
        out.clear();
        let states = self.bank.step();
        for (i, (&s, &px)) in states.iter().zip(&self.intensities).enumerate() {
            if u32::from(px) > (s & 0xFF) {
                out.push(i as u32);
            }
        }
    }
}

/// One-shot helper: the spike vector at a single timestep (timesteps are
/// 0-based; this replays the stream from scratch — use [`PoissonEncoder`]
/// for sequential access).
pub fn encode_step(img: &Image, seed: u32, timestep: u32) -> Vec<bool> {
    let mut enc = PoissonEncoder::new(img, seed);
    let mut out = vec![false; img.pixels.len()];
    for _ in 0..=timestep {
        enc.step_into(&mut out);
    }
    out
}

/// Full spike train for `timesteps` steps: `out[t][i]`.
pub fn encode_image(img: &Image, seed: u32, timesteps: u32) -> Vec<Vec<bool>> {
    let mut enc = PoissonEncoder::new(img, seed);
    (0..timesteps).map(|_| enc.step()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Image, IMG_PIXELS};
    use crate::testutil::PropRunner;

    fn flat(intensity: u8) -> Image {
        Image { label: 0, pixels: vec![intensity; IMG_PIXELS] }
    }

    #[test]
    fn zero_intensity_never_spikes() {
        let train = encode_image(&flat(0), 7, 50);
        assert!(train.iter().flatten().all(|&s| !s));
    }

    #[test]
    fn full_intensity_spikes_at_255_over_256() {
        // p(spike) for I=255 is 255/256; over many trials the rate should
        // be extremely high but not necessarily 1 per pixel.
        let train = encode_image(&flat(255), 7, 64);
        let total: usize = train.iter().flatten().filter(|&&s| s).count();
        let rate = total as f64 / (64.0 * IMG_PIXELS as f64);
        assert!(rate > 0.99, "rate {rate}");
    }

    #[test]
    fn rate_tracks_intensity() {
        // Paper's claim: firing rate ∝ intensity. Check I/256 within noise.
        for intensity in [32u8, 64, 128, 192] {
            let t = 200u32;
            let train = encode_image(&flat(intensity), 11, t);
            let total: usize = train.iter().flatten().filter(|&&s| s).count();
            let rate = total as f64 / (f64::from(t) * IMG_PIXELS as f64);
            let expect = f64::from(intensity) / 256.0;
            assert!(
                (rate - expect).abs() < 0.01,
                "I={intensity}: rate {rate:.4} vs expected {expect:.4}"
            );
        }
    }

    #[test]
    fn encode_step_matches_sequential() {
        let img = crate::data::DigitGen::new(1).sample(5, 2);
        let full = encode_image(&img, 3, 10);
        for t in 0..10u32 {
            let single = encode_step(&img, 3, t);
            assert_eq!(single, full[t as usize], "timestep {t}");
        }
    }

    #[test]
    fn step_active_matches_step() {
        let img = crate::data::DigitGen::new(1).sample(2, 5);
        let mut a = PoissonEncoder::new(&img, 9);
        let mut b = PoissonEncoder::new(&img, 9);
        let mut active = Vec::new();
        for t in 0..15 {
            let flags = a.step();
            b.step_active_into(&mut active);
            let from_active: Vec<bool> = {
                let mut v = vec![false; IMG_PIXELS];
                for &i in &active {
                    v[i as usize] = true;
                }
                v
            };
            assert_eq!(flags, from_active, "divergence at step {t}");
        }
    }

    #[test]
    fn different_seeds_differ() {
        let img = flat(128);
        assert_ne!(encode_image(&img, 1, 5), encode_image(&img, 2, 5));
    }

    #[test]
    fn prop_spike_rate_monotone_in_intensity() {
        // For any fixed seed and timestep budget, a brighter image's total
        // spike count dominates a darker one's when compared pixel-wise on
        // the SAME streams (monotonicity of the comparator).
        PropRunner::new("encoder_monotone", 50).run(|g| {
            let seed = g.rng.next_u32();
            let lo_v = g.rng.range_i32(0, 254) as u8;
            let hi_v = g.rng.range_i32(i32::from(lo_v) + 1, 255) as u8;
            let lo = encode_image(&flat(lo_v), seed, 20);
            let hi = encode_image(&flat(hi_v), seed, 20);
            for (lt, ht) in lo.iter().zip(&hi) {
                for (l, h) in lt.iter().zip(ht) {
                    assert!(!l | h, "darker pixel spiked where brighter did not");
                }
            }
        });
    }
}
