//! Behavioral LIF layer: the architectural timestep update (paper Eq. 1-2).
//!
//! Per timestep, for every enabled neuron `j`:
//!
//! 1. integrate: `acc_j = sat(acc_j + Σ_{i: S_i} W[i][j])`
//! 2. leak:      `acc_j = acc_j - (acc_j >> n)`
//! 3. fire:      `acc_j ≥ V_th` → spike, hard reset to `V_rest`
//! 4. prune:     after `after_spikes` fires the neuron's enable gates off
//!
//! The integration sum is accumulated in i64 and saturated once per step —
//! equivalent to the RTL's saturating adder because `Σ|W| ≤ 784·256 <
//! 2^18` can never overflow an i64, and the RTL applies saturation on a
//! 24-bit register whose bound we clamp to after the sum (proven equal in
//! the rtl equivalence tests; the RTL saturates per-add but with monotone
//! partial sums the end state matches — see `rtl::core` tests).

use crate::config::{PruneMode, SnnConfig};
use crate::error::{Error, Result};
use crate::fixed::{leak, sat_clamp, SparseWeightLayer, WeightMatrix};

/// Per-step observability record (drives Fig. 4 and the golden traces).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StepTrace {
    /// Membrane potential of every neuron *after* leak, *before* reset.
    pub membrane_pre_reset: Vec<i32>,
    /// Membrane potential after fire/reset.
    pub membrane: Vec<i32>,
    /// Which neurons fired this step.
    pub fired: Vec<bool>,
    /// Input current `Σ W_i·S_i` integrated this step, per neuron.
    pub input_current: Vec<i32>,
}

/// Stateful behavioral LIF layer.
#[derive(Debug, Clone)]
pub struct LifLayer {
    cfg: SnnConfig,
    /// Row-major weights (`w[i * n_outputs + j]`): integration walks the
    /// active inputs and streams each input's contiguous output row.
    /// Shared behind `Arc` so per-request layer clones are O(state), not
    /// O(weights) (perf pass 3).
    w_rows: std::sync::Arc<Vec<i32>>,
    acc: Vec<i32>,
    spike_counts: Vec<u32>,
    enabled: Vec<bool>,
    /// Number of integrate-add operations actually performed (sparsity
    /// accounting for the Table II comparison).
    adds_performed: u64,
    /// Reusable index buffer of the inputs that spiked this step.
    active_scratch: Vec<u32>,
    /// Reusable per-neuron current accumulator. i32 suffices: the per-step
    /// sum is bounded by `n_inputs · weight_max ≤ 784·256 ≈ 2·10^5`
    /// (perf pass 5: halves the SIMD lane width of the integration loop).
    current_scratch: Vec<i32>,
}

impl LifLayer {
    /// Build a layer; the weight geometry must match the config.
    pub fn new(cfg: SnnConfig, weights: &WeightMatrix) -> Result<Self> {
        if weights.n_inputs() != cfg.n_inputs() || weights.n_outputs() != cfg.n_outputs() {
            return Err(Error::ShapeMismatch(format!(
                "weights {}x{} vs config {}x{}",
                weights.n_inputs(),
                weights.n_outputs(),
                cfg.n_inputs(),
                cfg.n_outputs()
            )));
        }
        let n = cfg.n_outputs();
        let n_in = cfg.n_inputs();
        Ok(LifLayer {
            w_rows: std::sync::Arc::new(weights.as_slice().to_vec()),
            acc: vec![cfg.v_rest; n],
            spike_counts: vec![0; n],
            enabled: vec![true; n],
            cfg,
            adds_performed: 0,
            active_scratch: Vec::with_capacity(n_in),
            current_scratch: Vec::with_capacity(n),
        })
    }

    /// Reset all state for a new inference (keeps weights).
    pub fn reset(&mut self) {
        self.acc.fill(self.cfg.v_rest);
        self.spike_counts.fill(0);
        self.enabled.fill(true);
        self.adds_performed = 0;
    }

    /// Current membrane potentials.
    pub fn membrane(&self) -> &[i32] {
        &self.acc
    }

    /// Output spike counts so far.
    pub fn spike_counts(&self) -> &[u32] {
        &self.spike_counts
    }

    /// Which neurons are still enabled (false = pruned).
    pub fn enabled(&self) -> &[bool] {
        &self.enabled
    }

    /// Integrate-add operations performed so far (sparsity accounting).
    pub fn adds_performed(&self) -> u64 {
        self.adds_performed
    }

    /// Advance one timestep with the given input spike vector; returns the
    /// per-neuron output spike flags.
    pub fn step(&mut self, spikes_in: &[bool]) -> Vec<bool> {
        self.step_traced(spikes_in).fired
    }

    /// Allocation-free step for the serving hot path: identical dynamics
    /// to [`LifLayer::step_traced`] (property-tested equal) but writes the
    /// fire flags into a caller-provided buffer and records no trace
    /// (perf pass 3, EXPERIMENTS.md §Perf).
    pub fn step_into(&mut self, spikes_in: &[bool], fired_out: &mut [bool]) {
        assert_eq!(spikes_in.len(), self.cfg.n_inputs(), "input spike vector length");
        self.active_scratch.clear();
        for (i, &s) in spikes_in.iter().enumerate() {
            if s {
                self.active_scratch.push(i as u32);
            }
        }
        let active = std::mem::take(&mut self.active_scratch);
        self.step_events_into(&active, fired_out);
        self.active_scratch = active;
    }

    /// Event-list step (perf pass 4): like [`LifLayer::step_into`] but
    /// takes the spiking input *indices* directly — the fused
    /// encoder→integration hot path of the serving backend.
    pub fn step_events_into(&mut self, active: &[u32], fired_out: &mut [bool]) {
        let n_out = self.cfg.n_outputs();
        assert_eq!(fired_out.len(), n_out, "output flag buffer length");
        debug_assert!(active.iter().all(|&i| (i as usize) < self.cfg.n_inputs()));

        let n_enabled = self.enabled.iter().filter(|&&e| e).count() as u64;
        self.adds_performed += active.len() as u64 * n_enabled;

        self.current_scratch.clear();
        self.current_scratch.resize(n_out, 0i32);
        for &i in active {
            let row = &self.w_rows[i as usize * n_out..(i as usize + 1) * n_out];
            for (c, &w) in self.current_scratch.iter_mut().zip(row) {
                *c += w;
            }
        }

        for j in 0..n_out {
            fired_out[j] = false;
            if !self.enabled[j] {
                continue;
            }
            let integrated =
                sat_clamp(i64::from(self.acc[j]) + i64::from(self.current_scratch[j]), self.cfg.acc_bits);
            let leaked = leak(integrated, self.cfg.decay_shift);
            if leaked >= self.cfg.v_th {
                fired_out[j] = true;
                self.spike_counts[j] += 1;
                self.acc[j] = self.cfg.v_rest;
                if let PruneMode::AfterFires { after_spikes } = self.cfg.prune {
                    if self.spike_counts[j] >= after_spikes {
                        self.enabled[j] = false;
                    }
                }
            } else {
                self.acc[j] = leaked;
            }
        }
    }

    // pallas-lint: hot
    /// Event-list step over a CSR weight layer (the behavioral mirror of
    /// the RTL sparse sweep): integration touches only the retained
    /// synapses of each active input's row, and `adds_performed` credits
    /// only retained entries whose target neuron is still enabled — the
    /// event-rate accounting of EXPERIMENTS.md §Sparse. At prune
    /// threshold 0 the CSR keeps every entry, so dynamics *and* the adds
    /// count match [`LifLayer::step_events_into`] exactly (property-tested
    /// in `network.rs`).
    pub fn step_events_sparse_into(
        &mut self,
        active: &[u32],
        sparse: &SparseWeightLayer,
        fired_out: &mut [bool],
    ) {
        let n_out = self.cfg.n_outputs();
        assert_eq!(fired_out.len(), n_out, "output flag buffer length");
        assert_eq!(sparse.n_inputs(), self.cfg.n_inputs(), "sparse layer input width");
        assert_eq!(sparse.n_outputs(), n_out, "sparse layer output width");
        debug_assert!(active.iter().all(|&i| (i as usize) < self.cfg.n_inputs()));

        self.current_scratch.clear();
        self.current_scratch.resize(n_out, 0i32);
        // Pruning only flips enables in the fire loop below, so `enabled`
        // is constant across this accumulation: counting enabled retained
        // entries here equals `events × n_enabled` at threshold 0.
        for &i in active {
            let (cols, vals) = sparse.row(i as usize);
            for (&j, &w) in cols.iter().zip(vals) {
                let j = j as usize;
                self.current_scratch[j] += w;
                if self.enabled[j] {
                    self.adds_performed += 1;
                }
            }
        }

        for j in 0..n_out {
            fired_out[j] = false;
            if !self.enabled[j] {
                continue;
            }
            let integrated =
                sat_clamp(i64::from(self.acc[j]) + i64::from(self.current_scratch[j]), self.cfg.acc_bits);
            let leaked = leak(integrated, self.cfg.decay_shift);
            if leaked >= self.cfg.v_th {
                fired_out[j] = true;
                self.spike_counts[j] += 1;
                self.acc[j] = self.cfg.v_rest;
                if let PruneMode::AfterFires { after_spikes } = self.cfg.prune {
                    if self.spike_counts[j] >= after_spikes {
                        self.enabled[j] = false;
                    }
                }
            } else {
                self.acc[j] = leaked;
            }
        }
    }
    // pallas-lint: end-hot

    /// Advance one timestep, returning full observability.
    pub fn step_traced(&mut self, spikes_in: &[bool]) -> StepTrace {
        assert_eq!(spikes_in.len(), self.cfg.n_inputs(), "input spike vector length");
        let n_out = self.cfg.n_outputs();
        let mut input_current = vec![0i32; n_out];
        let mut fired = vec![false; n_out];
        let mut membrane_pre = vec![0i32; n_out];

        // Gather the active inputs once (≈30 % of pixels spike per step),
        // so integration touches only live events instead of scanning all
        // 784 flags per neuron — the software analogue of the hardware's
        // event-driven gating. (Perf pass 1, EXPERIMENTS.md §Perf.)
        self.active_scratch.clear();
        for (i, &s) in spikes_in.iter().enumerate() {
            if s {
                self.active_scratch.push(i as u32);
            }
        }
        let n_enabled = self.enabled.iter().filter(|&&e| e).count() as u64;
        self.adds_performed += self.active_scratch.len() as u64 * n_enabled;

        // Accumulate per-neuron currents input-major: each active input
        // adds its contiguous 10-wide weight row into the current vector —
        // sequential loads, auto-vectorizable (perf pass 2). Partial sums
        // cannot overflow i64 (≤ 784·256 per step).
        self.current_scratch.clear();
        self.current_scratch.resize(n_out, 0i32);
        for &i in &self.active_scratch {
            let row = &self.w_rows[i as usize * n_out..(i as usize + 1) * n_out];
            for (c, &w) in self.current_scratch.iter_mut().zip(row) {
                *c += w;
            }
        }

        for j in 0..n_out {
            if !self.enabled[j] {
                membrane_pre[j] = self.acc[j];
                continue;
            }
            // 1. Integrate. Sum accumulated above; saturate once into the
            //    register width (see module docs for the RTL equivalence
            //    argument).
            let sum: i32 = self.current_scratch[j];
            input_current[j] = sum;
            let integrated = sat_clamp(i64::from(self.acc[j]) + i64::from(sum), self.cfg.acc_bits);
            // 2. Leak.
            let leaked = leak(integrated, self.cfg.decay_shift);
            membrane_pre[j] = leaked;
            // 3. Fire & reset.
            if leaked >= self.cfg.v_th {
                fired[j] = true;
                self.spike_counts[j] += 1;
                self.acc[j] = self.cfg.v_rest;
                // 4. Prune.
                if let PruneMode::AfterFires { after_spikes } = self.cfg.prune {
                    if self.spike_counts[j] >= after_spikes {
                        self.enabled[j] = false;
                    }
                }
            } else {
                self.acc[j] = leaked;
            }
        }

        StepTrace {
            membrane_pre_reset: membrane_pre,
            membrane: self.acc.clone(),
            fired,
            input_current,
        }
    }
}

// ---------------------------------------------------------------------------

/// One layer × a whole sub-batch of the behavioral model: per-image
/// accumulator/count/enable planes (`plane[j * lanes + b]`, neuron-major
/// so the row-reuse current add is one contiguous sweep across lanes)
/// over the layer's shared `Arc`'d weights.
#[derive(Debug, Clone)]
struct LifBatchLayer {
    /// The narrowed single-layer config (per-layer params resolved).
    cfg: SnnConfig,
    w_rows: std::sync::Arc<Vec<i32>>,
    acc: Vec<i32>,
    spike_counts: Vec<u32>,
    enabled: Vec<bool>,
    /// Integrate-adds actually performed, per lane.
    adds_performed: Vec<u64>,
    /// Per-lane input-current accumulation plane for the current step.
    current: Vec<i32>,
}

/// The batched behavioral engine: a [`LifStack`] with a batch dimension.
/// One [`LifBatchStack::step_batch`] call advances every live image of a
/// sub-batch through one timestep, relaying each layer's per-image fired
/// vectors (as bitset-transposed masks — `fired[l][j]` bit `b` = image
/// `b`'s neuron `j` fired) into the next layer's event set, so each
/// weight row is read **once** per timestep and its current is added into
/// every image whose input fired.
///
/// Per-image dynamics are identical to [`LifLayer::step_events_into`]
/// (same saturation/leak/fire/prune update, same `adds_performed`
/// accounting) — lanes share nothing but the weights, so batching only
/// reorders work across images. Pinned against the sequential path by
/// `batched_inference_equals_sequential`.
///
/// Masks are multi-word: `lane_words = lanes.div_ceil(64)` words per
/// input/neuron, lane `b` at word `b / 64`, bit `b % 64` — mirroring the
/// RTL batch engine's layout so both engine families stay structurally
/// parallel.
#[derive(Debug, Clone)]
pub struct LifBatchStack {
    layers: Vec<LifBatchLayer>,
    lanes: usize,
    /// Words per transposed mask row for the current batch width.
    lane_words: usize,
    /// Widest layer input (sizes the layer-0 mask scratch).
    max_in: usize,
    /// Layer-0 transposed input-mask scratch, `masks[i * lane_words + wb]`.
    masks: Vec<u64>,
    /// Per-layer transposed fire masks for the current step (the relay),
    /// `fired_masks[l][j * lane_words + wb]`.
    fired_masks: Vec<Vec<u64>>,
    /// Per-layer, per-lane fire counts this step (the next layer's
    /// event-list lengths, for adds accounting).
    fired_len: Vec<Vec<u32>>,
}

impl LifBatchStack {
    /// Batch lanes one stack multiplexes; larger sub-batches are chunked
    /// by the caller. Aliases [`crate::plan::MAX_LANES`] — the single
    /// source of the lane-width ceiling — so this engine and the RTL
    /// engine's `BATCH_LANES` cannot drift apart. Callers typically
    /// chunk by a calibrated [`crate::plan::ChunkPlan`] width (≤ this
    /// ceiling) rather than the ceiling itself.
    pub const MAX_LANES: usize = crate::plan::MAX_LANES;

    /// Build from a stack's layers, sharing their weight `Arc`s (state
    /// planes start empty; [`LifBatchStack::reset`] sizes them per batch).
    pub(crate) fn from_layers(layers: &[LifLayer]) -> Self {
        let max_in = layers.iter().map(|l| l.cfg.n_inputs()).max().unwrap_or(0);
        LifBatchStack {
            layers: layers
                .iter()
                .map(|l| LifBatchLayer {
                    cfg: l.cfg.clone(),
                    w_rows: std::sync::Arc::clone(&l.w_rows),
                    acc: Vec::new(),
                    spike_counts: Vec::new(),
                    enabled: Vec::new(),
                    adds_performed: Vec::new(),
                    current: Vec::new(),
                })
                .collect(),
            lanes: 0,
            lane_words: 1,
            max_in,
            masks: Vec::new(),
            fired_masks: layers.iter().map(|_| Vec::new()).collect(),
            fired_len: layers.iter().map(|_| Vec::new()).collect(),
        }
    }

    /// Number of weight layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Current batch width.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Reset for a fresh sub-batch of `lanes` images (≤ `MAX_LANES`):
    /// every lane starts with `v_rest` accumulators, zero counts, full
    /// enables — exactly [`LifStack::reset`], per image.
    pub fn reset(&mut self, lanes: usize) {
        assert!(lanes <= Self::MAX_LANES, "batch chunk exceeds {} lanes", Self::MAX_LANES);
        self.lanes = lanes;
        self.lane_words = lanes.div_ceil(64).max(1);
        for layer in &mut self.layers {
            let n = layer.cfg.n_outputs();
            layer.acc.clear();
            layer.acc.resize(lanes * n, layer.cfg.v_rest);
            layer.spike_counts.clear();
            layer.spike_counts.resize(lanes * n, 0);
            layer.enabled.clear();
            layer.enabled.resize(lanes * n, true);
            layer.adds_performed.clear();
            layer.adds_performed.resize(lanes, 0);
            layer.current.clear();
            layer.current.resize(lanes * n, 0);
        }
        for fl in &mut self.fired_len {
            fl.clear();
            fl.resize(lanes, 0);
        }
        self.masks.clear();
        self.masks.resize(self.max_in * self.lane_words, 0);
        for (fm, layer) in self.fired_masks.iter_mut().zip(&self.layers) {
            fm.clear();
            fm.resize(layer.cfg.n_outputs() * self.lane_words, 0);
        }
    }

    // pallas-lint: hot
    /// Advance one timestep for every lane in `live`, chaining each
    /// layer's fired masks into the next layer's event set. `active[b]`
    /// is lane `b`'s layer-0 event list (spiking input indices); entries
    /// of retired lanes are ignored.
    pub fn step_batch(&mut self, live: &[usize], active: &[Vec<u32>]) {
        for fm in &mut self.fired_masks {
            fm.fill(0);
        }
        let n_layers = self.layers.len();
        let (lanes, lw) = (self.lanes, self.lane_words);
        for l in 0..n_layers {
            let n_in = self.layers[l].cfg.n_inputs();
            let n_out = self.layers[l].cfg.n_outputs();

            // Clear the current planes (retired lanes' entries are never
            // read) and account this step's integrate adds (events ×
            // enabled neurons, counted at step entry exactly like
            // `step_events_into`).
            {
                let layer = &mut self.layers[l];
                layer.current.fill(0);
                for &b in live {
                    let n_enabled =
                        (0..n_out).filter(|&j| layer.enabled[j * lanes + b]).count() as u64;
                    let events = if l == 0 {
                        active[b].len() as u64
                    } else {
                        u64::from(self.fired_len[l - 1][b])
                    };
                    layer.adds_performed[b] += events * n_enabled;
                }
            }

            // Build the transposed input masks (layer 0 from the encoder
            // event lists; deeper layers read the previous layer's fire
            // masks directly) and run the row-reuse sweep: each weight
            // row is fetched once and added into every firing lane's
            // current — neuron-major, so the add is a contiguous sweep
            // across lanes (all-set words take the full-word fast path).
            // Ascending `i` keeps per-lane sums in the sequential order;
            // the plain integer add commutes across lanes.
            if l == 0 {
                self.masks[..n_in * lw].fill(0);
                for &b in live {
                    let (wb, bit) = (b / 64, b % 64);
                    for &i in &active[b] {
                        self.masks[i as usize * lw + wb] |= 1u64 << bit;
                    }
                }
            }
            {
                let layer = &mut self.layers[l];
                let (w_rows, current) = (&layer.w_rows, &mut layer.current);
                let src: &[u64] =
                    if l == 0 { &self.masks[..n_in * lw] } else { &self.fired_masks[l - 1] };
                for i in 0..n_in {
                    let mw = &src[i * lw..(i + 1) * lw];
                    if mw.iter().all(|&m| m == 0) {
                        continue;
                    }
                    let row = &w_rows[i * n_out..(i + 1) * n_out];
                    for (j, &w) in row.iter().enumerate() {
                        let cur = &mut current[j * lanes..(j + 1) * lanes];
                        for (wb, &m) in mw.iter().enumerate() {
                            if m == u64::MAX {
                                // All 64 lanes of this word take the add.
                                for c in &mut cur[wb * 64..wb * 64 + 64] {
                                    *c += w;
                                }
                            } else {
                                let mut m = m;
                                while m != 0 {
                                    let b = wb * 64 + m.trailing_zeros() as usize;
                                    m &= m - 1;
                                    cur[b] += w;
                                }
                            }
                        }
                    }
                }
            }

            // Integrate/leak/fire/prune per live lane — the exact
            // `step_events_into` neuron update, plane-addressed.
            let layer = &mut self.layers[l];
            let fired_masks_l = &mut self.fired_masks[l];
            let fired_len_l = &mut self.fired_len[l];
            for &b in live {
                let (wb, bit) = (b / 64, b % 64);
                let mut fires = 0u32;
                for j in 0..n_out {
                    let idx = j * lanes + b;
                    if !layer.enabled[idx] {
                        continue;
                    }
                    let integrated = sat_clamp(
                        i64::from(layer.acc[idx]) + i64::from(layer.current[idx]),
                        layer.cfg.acc_bits,
                    );
                    let leaked = leak(integrated, layer.cfg.decay_shift);
                    if leaked >= layer.cfg.v_th {
                        fired_masks_l[j * lw + wb] |= 1u64 << bit;
                        fires += 1;
                        layer.spike_counts[idx] += 1;
                        layer.acc[idx] = layer.cfg.v_rest;
                        if let PruneMode::AfterFires { after_spikes } = layer.cfg.prune {
                            if layer.spike_counts[idx] >= after_spikes {
                                layer.enabled[idx] = false;
                            }
                        }
                    } else {
                        layer.acc[idx] = leaked;
                    }
                }
                fired_len_l[b] = fires;
            }
        }
    }
    // pallas-lint: end-hot

    /// Lane `b`'s final-layer spike counts, gathered from the
    /// neuron-major plane.
    pub fn spike_counts(&self, b: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.extend_spike_counts(b, &mut out);
        out
    }

    /// Append lane `b`'s final-layer spike counts to `out` (the
    /// allocation-free gather for hot loops).
    pub fn extend_spike_counts(&self, b: usize, out: &mut Vec<u32>) {
        let layer = self.layers.last().expect("stack has at least one layer");
        let n = layer.cfg.n_outputs();
        out.extend((0..n).map(|j| layer.spike_counts[j * self.lanes + b]));
    }

    /// Did lane `b`'s output neuron `j` fire on the last step?
    pub fn output_fired(&self, b: usize, j: usize) -> bool {
        let fm = self.fired_masks.last().expect("stack has at least one layer");
        fm[j * self.lane_words + b / 64] >> (b % 64) & 1 == 1
    }

    /// Lane `b`'s integrate-adds, summed over every layer.
    pub fn adds_performed(&self, b: usize) -> u64 {
        self.layers.iter().map(|l| l.adds_performed[b]).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PruneMode, SnnConfig};
    use crate::testutil::PropRunner;

    fn tiny_cfg() -> SnnConfig {
        SnnConfig {
            topology: vec![4, 2],
            v_th: 10,
            v_rest: 0,
            decay_shift: 1,
            acc_bits: 16,
            weight_bits: 9,
            timesteps: 10,
            ..SnnConfig::paper()
        }
    }

    fn layer(cfg: &SnnConfig, w: Vec<i32>) -> LifLayer {
        let m = WeightMatrix::from_rows(cfg.n_inputs(), cfg.n_outputs(), cfg.weight_bits, w).unwrap();
        LifLayer::new(cfg.clone(), &m).unwrap()
    }

    #[test]
    fn hand_computed_trajectory() {
        // Neuron 0 weights [3, 4, 0, 0], neuron 1 weights [0, 0, 2, -2].
        // Row-major by input: w[i][j].
        let cfg = tiny_cfg();
        let mut l = layer(&cfg, vec![3, 0, 4, 0, 0, 2, 0, -2]);

        // Step 1: inputs 1,1,0,0 → n0 integrates 7, leak(7,1) = 7-3 = 4.
        let t = l.step_traced(&[true, true, false, false]);
        assert_eq!(t.input_current, vec![7, 0]);
        assert_eq!(t.membrane, vec![4, 0]);
        assert_eq!(t.fired, vec![false, false]);

        // Step 2: same input → acc 4+7 = 11, leak → 11-5 = 6.
        let t = l.step_traced(&[true, true, false, false]);
        assert_eq!(t.membrane, vec![6, 0]);

        // Step 3: same → 6+7 = 13, leak → 13-6 = 7.
        let t = l.step_traced(&[true, true, false, false]);
        assert_eq!(t.membrane, vec![7, 0]);

        // Step 4: 7+7 = 14, leak → 14-7 = 7 < 10: note the decay/threshold
        // equilibrium — raise drive via all four inputs: n0 +7, n1 0.
        let t = l.step_traced(&[true, true, true, true]);
        assert_eq!(t.input_current, vec![7, 0]);
        assert_eq!(t.membrane, vec![7, 0]);

        // Push neuron 0 over threshold with repeated max drive... it sits
        // at the fixed point 7; lower the threshold path instead by testing
        // fire directly below.
    }

    #[test]
    fn fire_and_hard_reset() {
        let cfg = SnnConfig { v_th: 5, ..tiny_cfg() };
        let mut l = layer(&cfg, vec![6, 0, 0, 0, 0, 0, 0, 0]);
        let t = l.step_traced(&[true, false, false, false]);
        // integrate 6, leak(6,1) = 3 < 5 → no fire.
        assert_eq!(t.membrane, vec![3, 0]);
        let t = l.step_traced(&[true, false, false, false]);
        // 3+6 = 9, leak → 9-4 = 5 ≥ 5 → fire, reset to 0.
        assert!(t.fired[0]);
        assert_eq!(t.membrane_pre_reset[0], 5);
        assert_eq!(t.membrane[0], 0);
        assert_eq!(l.spike_counts()[0], 1);
    }

    #[test]
    fn pruning_gates_neuron_off() {
        let cfg = SnnConfig {
            v_th: 5,
            prune: PruneMode::AfterFires { after_spikes: 1 },
            ..tiny_cfg()
        };
        let mut l = layer(&cfg, vec![12, 0, 0, 0, 0, 0, 0, 0]);
        let t = l.step_traced(&[true, false, false, false]);
        assert!(t.fired[0]);
        assert!(!l.enabled()[0], "neuron must be pruned after first fire");
        let before_adds = l.adds_performed();
        // Further steps must not integrate, fire, or count adds for n0;
        // neuron 1 (still enabled) performs exactly 4 adds for 4 spikes.
        let t = l.step_traced(&[true, true, true, true]);
        assert!(!t.fired[0]);
        assert_eq!(t.membrane[0], 0);
        assert_eq!(l.spike_counts()[0], 1);
        assert_eq!(
            l.adds_performed(),
            before_adds + 4,
            "pruned neuron must contribute zero adds (only n1's 4 expected)"
        );
    }

    #[test]
    fn prune_off_keeps_firing() {
        let cfg = SnnConfig { v_th: 5, prune: PruneMode::Off, ..tiny_cfg() };
        let mut l = layer(&cfg, vec![12, 0, 0, 0, 0, 0, 0, 0]);
        for _ in 0..4 {
            l.step(&[true, false, false, false]);
        }
        assert_eq!(l.spike_counts()[0], 4);
        assert!(l.enabled()[0]);
    }

    #[test]
    fn negative_weights_inhibit() {
        let cfg = tiny_cfg();
        let mut l = layer(&cfg, vec![-8, 0, 0, 0, 0, 0, 0, 0]);
        let t = l.step_traced(&[true, false, false, false]);
        // integrate -8, leak(-8,1) = -8 - (-4) = -4.
        assert_eq!(t.membrane, vec![-4, 0]);
        // Membrane decays back toward 0 with no input.
        let t = l.step_traced(&[false, false, false, false]);
        assert_eq!(t.membrane, vec![-2, 0]);
    }

    #[test]
    fn reset_restores_fresh_state() {
        let cfg = tiny_cfg();
        let mut l = layer(&cfg, vec![6, 0, 0, 0, 0, 0, 0, 0]);
        l.step(&[true, true, true, true]);
        l.reset();
        assert_eq!(l.membrane(), &[0, 0]);
        assert_eq!(l.spike_counts(), &[0, 0]);
        assert_eq!(l.enabled(), &[true, true]);
        assert_eq!(l.adds_performed(), 0);
    }

    #[test]
    fn saturation_bounds_membrane() {
        // acc_bits = 8 → bound ±127; huge positive drive must clamp, and
        // with v_th above the clamp the neuron can never fire.
        let cfg = SnnConfig { acc_bits: 8, v_th: 127, v_rest: 0, ..tiny_cfg() };
        let mut l = layer(&cfg, vec![255, 0, 255, 0, 255, 0, 255, 0]);
        let t = l.step_traced(&[true, true, true, true]);
        // sum = 1020 → clamp 127 → leak(127,1) = 127-63 = 64.
        assert_eq!(t.membrane[0], 64);
    }

    #[test]
    fn prop_membrane_always_within_register_bounds() {
        PropRunner::new("lif_register_bounds", 200).run(|g| {
            let cfg = SnnConfig {
                topology: vec![16, 4],
                acc_bits: g.rng.range_i32(8, 24) as u32,
                v_th: g.rng.range_i32(1, 100),
                decay_shift: g.rng.range_i32(1, 6) as u32,
                ..SnnConfig::paper()
            }
            .validated();
            let cfg = match cfg {
                Ok(c) => c,
                Err(_) => return, // v_th > acc_max draw; skip
            };
            let w = g.vec_i32(16 * 4, -256, 255);
            let mut l = layer(&cfg, w);
            for _ in 0..30 {
                let spikes: Vec<bool> = (0..16).map(|_| g.rng.next_u32() & 1 == 1).collect();
                let t = l.step_traced(&spikes);
                for &m in &t.membrane {
                    assert!(
                        m >= cfg.acc_min() && m <= cfg.acc_max(),
                        "membrane {m} escaped ±{}",
                        cfg.acc_max()
                    );
                    assert!(m < cfg.v_th, "membrane at/above threshold survived reset");
                }
            }
        });
    }

    #[test]
    fn prop_step_into_equals_step_traced() {
        // The fast serving path must implement identical dynamics to the
        // traced path across random weights, configs and spike streams.
        PropRunner::new("step_into_equiv", 150).run(|g| {
            let cfg = SnnConfig {
                topology: vec![24, 5],
                v_th: g.rng.range_i32(5, 80),
                decay_shift: g.rng.range_i32(1, 5) as u32,
                acc_bits: 20,
                prune: *g.choice(&[
                    PruneMode::Off,
                    PruneMode::AfterFires { after_spikes: 1 },
                    PruneMode::AfterFires { after_spikes: 3 },
                ]),
                ..SnnConfig::paper()
            };
            let w = g.vec_i32(24 * 5, -60, 60);
            let m = WeightMatrix::from_rows(24, 5, 9, w).unwrap();
            let mut a = LifLayer::new(cfg.clone(), &m).unwrap();
            let mut b = LifLayer::new(cfg, &m).unwrap();
            let mut fired_fast = vec![false; 5];
            for step in 0..30 {
                let spikes: Vec<bool> = (0..24).map(|_| g.rng.next_u32() & 1 == 1).collect();
                let trace = a.step_traced(&spikes);
                b.step_into(&spikes, &mut fired_fast);
                assert_eq!(trace.fired, fired_fast, "fired diverges at step {step}");
                assert_eq!(a.membrane(), b.membrane(), "membrane diverges at step {step}");
                assert_eq!(a.spike_counts(), b.spike_counts(), "counts diverge at {step}");
                assert_eq!(a.enabled(), b.enabled(), "enables diverge at {step}");
                assert_eq!(a.adds_performed(), b.adds_performed(), "adds diverge at {step}");
            }
        });
    }

    #[test]
    fn prop_sparse_events_equal_dense_at_threshold_zero() {
        // The CSR event step must be a drop-in mirror of the dense event
        // step: identical membranes, fires, counts, enables, AND the same
        // adds_performed — threshold 0 keeps every entry, so enabled
        // retained entries per step = events × n_enabled.
        PropRunner::new("lif_sparse_equiv", 120).run(|g| {
            let cfg = SnnConfig {
                topology: vec![24, 5],
                v_th: g.rng.range_i32(5, 80),
                decay_shift: g.rng.range_i32(1, 5) as u32,
                acc_bits: 20,
                prune: *g.choice(&[
                    PruneMode::Off,
                    PruneMode::AfterFires { after_spikes: 1 },
                    PruneMode::AfterFires { after_spikes: 3 },
                ]),
                ..SnnConfig::paper()
            };
            let w = g.vec_i32(24 * 5, -60, 60);
            let m = WeightMatrix::from_rows(24, 5, 9, w).unwrap();
            let sparse0 = crate::fixed::SparseWeightLayer::from_dense(&m, 0);
            let threshold = g.rng.range_i32(10, 40);
            let sparse_t = crate::fixed::SparseWeightLayer::from_dense(&m, threshold);
            let pruned = sparse_t.to_dense();
            let mut dense = LifLayer::new(cfg.clone(), &m).unwrap();
            let mut mirror = LifLayer::new(cfg.clone(), &m).unwrap();
            // Above threshold 0, the sparse step over `m`'s CSR equals the
            // *dense* step over the pruned re-densification — zero-weight
            // adds are state-neutral — except adds_performed, which only
            // credits retained synapses.
            let mut dense_pruned = LifLayer::new(cfg.clone(), &pruned).unwrap();
            let mut mirror_pruned = LifLayer::new(cfg, &pruned).unwrap();
            let mut fired_a = vec![false; 5];
            let mut fired_b = vec![false; 5];
            for step in 0..30 {
                let spikes: Vec<bool> = (0..24).map(|_| g.rng.next_u32() & 1 == 1).collect();
                let active: Vec<u32> = spikes
                    .iter()
                    .enumerate()
                    .filter_map(|(i, &s)| s.then_some(i as u32))
                    .collect();
                dense.step_events_into(&active, &mut fired_a);
                mirror.step_events_sparse_into(&active, &sparse0, &mut fired_b);
                assert_eq!(fired_a, fired_b, "fired diverges at step {step}");
                assert_eq!(dense.membrane(), mirror.membrane(), "membrane at {step}");
                assert_eq!(dense.spike_counts(), mirror.spike_counts(), "counts at {step}");
                assert_eq!(dense.enabled(), mirror.enabled(), "enables at {step}");
                assert_eq!(
                    dense.adds_performed(),
                    mirror.adds_performed(),
                    "adds diverge at step {step}"
                );

                dense_pruned.step_events_into(&active, &mut fired_a);
                mirror_pruned.step_events_sparse_into(&active, &sparse_t, &mut fired_b);
                assert_eq!(fired_a, fired_b, "pruned fired diverges at step {step}");
                assert_eq!(dense_pruned.membrane(), mirror_pruned.membrane());
                assert_eq!(dense_pruned.spike_counts(), mirror_pruned.spike_counts());
                assert_eq!(dense_pruned.enabled(), mirror_pruned.enabled());
                assert!(
                    mirror_pruned.adds_performed() <= dense_pruned.adds_performed(),
                    "sparse must never credit more adds than the dense walk"
                );
            }
        });
    }

    #[test]
    fn prop_spike_counts_monotone_and_bounded() {
        PropRunner::new("lif_spike_counts", 100).run(|g| {
            let cfg = SnnConfig {
                topology: vec![8, 3],
                v_th: 20,
                decay_shift: 2,
                acc_bits: 16,
                prune: PruneMode::Off,
                ..SnnConfig::paper()
            };
            let w = g.vec_i32(8 * 3, -50, 50);
            let mut l = layer(&cfg, w);
            let mut prev = vec![0u32; 3];
            let steps = 25u32;
            for _ in 0..steps {
                let spikes: Vec<bool> = (0..8).map(|_| g.rng.next_u32() & 1 == 1).collect();
                l.step(&spikes);
                for (a, b) in l.spike_counts().iter().zip(&prev) {
                    assert!(a >= b, "spike count decreased");
                }
                prev = l.spike_counts().to_vec();
            }
            assert!(prev.iter().all(|&c| c <= steps));
        });
    }
}
