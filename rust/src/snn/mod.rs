//! Behavioral (timestep-level) golden model of the paper's SNN core.
//!
//! This is the *architectural contract*: the cycle-accurate RTL simulator
//! ([`crate::rtl`]) refines it to clock granularity and is checked against
//! it by equivalence tests; the JAX/Pallas path
//! (`python/compile/model.py`) implements the same dynamics and is checked
//! via golden traces and live PJRT execution. It is also the fastest pure-
//! Rust inference backend, used for large accuracy sweeps.

mod encoder;
mod lif;
mod network;

pub use encoder::{encode_image, encode_step, PoissonEncoder};
pub use lif::{LifBatchStack, LifLayer, StepTrace};
pub use network::{classify, classify_with_trace, BehavioralNet, Classification, EarlyExit, LifStack};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SnnConfig;
    use crate::data::DigitGen;
    use crate::fixed::WeightMatrix;

    /// End-to-end smoke: random-ish weights still produce a decision and
    /// spike counts bounded by the timestep budget.
    #[test]
    fn classify_produces_bounded_counts() {
        let cfg = SnnConfig::paper().with_timesteps(8).validated().unwrap();
        let w = WeightMatrix::from_rows(
            784,
            10,
            9,
            (0..7840).map(|i| ((i * 37) % 11) as i32 - 5).collect(),
        )
        .unwrap();
        let net = BehavioralNet::new(cfg.clone(), w).unwrap();
        let img = DigitGen::new(1).sample(3, 0);
        let out = net.classify(&img, 99);
        assert!(out.class <= 9);
        assert_eq!(out.spike_counts.len(), 10);
        assert!(out.spike_counts.iter().all(|&c| c <= cfg.timesteps));
        assert!(out.steps_run <= cfg.timesteps);
    }
}
