//! Full behavioral inference: encoder + chained LIF layers + readout.
//!
//! Since the N-layer refactor the behavioral model runs a [`LifStack`] — a
//! chain of [`LifLayer`]s matching `SnnConfig::topology`. Within one
//! timestep each layer's fired vector feeds the next layer's event-driven
//! integration (`step_events_into`), so a spike propagates through the
//! whole depth in a single architectural step, exactly as the RTL core
//! time-multiplexes its layer walks inside one timestep. The decision,
//! early-exit margin and spike counts read from the final layer;
//! `adds_performed` sums the integrate work of every layer (sparsity
//! accounting stays whole-network).

use crate::config::{DecisionPolicy, SnnConfig};
use crate::data::Image;
use crate::error::{Error, Result};
use crate::fixed::{SparseWeightStack, WeightStack};
use crate::snn::{LifBatchStack, LifLayer, PoissonEncoder, StepTrace};
use crate::util::{margin_reached, priority_argmax};

/// Early-termination policy applied between timesteps (the serving-level
/// generalization of the paper's active-pruning idea: stop paying for
/// timesteps once the decision is confident).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EarlyExit {
    /// Run the full window.
    Off,
    /// Stop once the leading class's spike count exceeds the runner-up by
    /// `margin` *and* at least `min_steps` have run.
    ///
    /// Note the interaction with neuron-level pruning: with the paper's
    /// `PruneMode::AfterFires { after_spikes: 1 }` every spike count is
    /// capped at 1, so the reachable margin is 1. Margins above the
    /// output layer's cap are clamped at inference entry
    /// ([`EarlyExit::clamped_for`]) instead of silently running the full
    /// window.
    Margin { margin: u32, min_steps: u32 },
}

impl EarlyExit {
    /// Clamp an unreachable margin down to the output layer's pruning cap
    /// ([`SnnConfig::max_reachable_margin`]). With `AfterFires(a)` on the
    /// readout every spike count saturates at `a`, so `margin > a` could
    /// never trigger — historically that silently disabled early exit and
    /// ran the full window. Both inference engines (behavioral
    /// `run_inference` and `RtlCore::run_fast_early`) call this at entry,
    /// so the clamped policy — and therefore `steps_run` — stays identical
    /// across them. Warns once per process on the first clamp.
    pub fn clamped_for(self, cfg: &SnnConfig) -> EarlyExit {
        let EarlyExit::Margin { margin, min_steps } = self else { return self };
        let Some(cap) = cfg.max_reachable_margin() else { return self };
        if margin <= cap {
            return self;
        }
        static WARNED: std::sync::Once = std::sync::Once::new();
        WARNED.call_once(|| {
            eprintln!(
                "warning: EarlyExit margin {margin} is unreachable under the output \
                 layer's prune cap {cap}; clamping to {cap} (raise after_spikes or \
                 disable readout pruning for larger margins)"
            );
        });
        EarlyExit::Margin { margin: cap, min_steps }
    }
}

/// Inference result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Classification {
    /// Predicted class.
    pub class: u8,
    /// Output spike counts per class over the executed window (final
    /// layer).
    pub spike_counts: Vec<u32>,
    /// Timestep at which each output neuron first fired (`None` = never).
    pub first_spike: Vec<Option<u32>>,
    /// Timesteps actually executed (< window when early exit triggers).
    pub steps_run: u32,
    /// Integrate-adds actually performed across all layers (sparsity
    /// accounting).
    pub adds_performed: u64,
}

impl Classification {
    /// Decide a class from spike evidence under `policy`. Ties break toward
    /// the lowest class index — the behaviour of a hardware priority
    /// encoder scanning `spike_reg[0..9]`.
    fn decide(
        policy: DecisionPolicy,
        spike_counts: &[u32],
        first_spike: &[Option<u32>],
    ) -> u8 {
        match policy {
            DecisionPolicy::SpikeCount => priority_argmax(spike_counts) as u8,
            DecisionPolicy::FirstSpike => {
                let mut best: Option<(u32, usize)> = None;
                for (j, fs) in first_spike.iter().enumerate() {
                    if let Some(t) = fs {
                        if best.map_or(true, |(bt, _)| *t < bt) {
                            best = Some((*t, j));
                        }
                    }
                }
                match best {
                    Some((_, j)) => j as u8,
                    None => priority_argmax(spike_counts) as u8,
                }
            }
        }
    }
}

/// The chained per-layer state of one inference engine instance: the
/// poolable unit the serving backend checks out per batch. Weights are
/// shared behind `Arc` inside each [`LifLayer`], so clones are O(state).
#[derive(Debug, Clone)]
pub struct LifStack {
    layers: Vec<LifLayer>,
    /// Per-layer fired scratch (`fired[l][j]`), reused across steps.
    fired: Vec<Vec<bool>>,
    /// Reusable index buffer carrying one layer's spikes into the next.
    relay: Vec<u32>,
}

impl LifStack {
    /// Build the chain; the stack's topology must match the config's.
    pub fn new(cfg: &SnnConfig, weights: &WeightStack) -> Result<Self> {
        weights.check_topology(&cfg.topology)?;
        let mut layers = Vec::with_capacity(cfg.n_layers());
        for l in 0..cfg.n_layers() {
            layers.push(LifLayer::new(cfg.layer_config(l), weights.layer(l))?);
        }
        let fired = (0..cfg.n_layers()).map(|l| vec![false; cfg.layer_output(l)]).collect();
        Ok(LifStack { layers, fired, relay: Vec::new() })
    }

    /// Number of weight layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Layer `l` (observability).
    pub fn layer(&self, l: usize) -> &LifLayer {
        &self.layers[l]
    }

    /// The final (output) layer.
    pub fn output(&self) -> &LifLayer {
        self.layers.last().expect("stack has at least one layer")
    }

    /// Final-layer spike counts so far.
    pub fn spike_counts(&self) -> &[u32] {
        self.output().spike_counts()
    }

    /// Integrate-adds performed so far, summed over every layer.
    pub fn adds_performed(&self) -> u64 {
        self.layers.iter().map(LifLayer::adds_performed).sum()
    }

    /// Reset all per-inference state (keeps weights).
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.reset();
        }
    }

    /// Advance one timestep from an active-input index list, chaining each
    /// layer's fired vector into the next layer's event list. Writes the
    /// final layer's fire flags into `fired_out`.
    pub fn step_events_into(&mut self, active: &[u32], fired_out: &mut [bool]) {
        let n = self.layers.len();
        for l in 0..n {
            if l == 0 {
                self.layers[0].step_events_into(active, &mut self.fired[0]);
            } else {
                self.relay.clear();
                for (i, &f) in self.fired[l - 1].iter().enumerate() {
                    if f {
                        self.relay.push(i as u32);
                    }
                }
                let relay = std::mem::take(&mut self.relay);
                self.layers[l].step_events_into(&relay, &mut self.fired[l]);
                self.relay = relay;
            }
        }
        fired_out.copy_from_slice(&self.fired[n - 1]);
    }

    /// The CSR mirror of [`LifStack::step_events_into`]: each layer
    /// integrates only the retained synapses of its active inputs' rows
    /// (the behavioral silence-skipping sweep). `sparse` must share this
    /// stack's topology; at prune threshold 0 the dynamics and
    /// `adds_performed` match the dense event path exactly.
    pub fn step_events_sparse_into(
        &mut self,
        sparse: &SparseWeightStack,
        active: &[u32],
        fired_out: &mut [bool],
    ) {
        let n = self.layers.len();
        for l in 0..n {
            if l == 0 {
                self.layers[0].step_events_sparse_into(active, sparse.layer(0), &mut self.fired[0]);
            } else {
                self.relay.clear();
                for (i, &f) in self.fired[l - 1].iter().enumerate() {
                    if f {
                        self.relay.push(i as u32);
                    }
                }
                let relay = std::mem::take(&mut self.relay);
                self.layers[l].step_events_sparse_into(&relay, sparse.layer(l), &mut self.fired[l]);
                self.relay = relay;
            }
        }
        fired_out.copy_from_slice(&self.fired[n - 1]);
    }

    /// A batched mirror of this stack: per-image state planes over the
    /// same shared weights ([`LifBatchStack`]; the poolable unit of the
    /// batched serving backend — cheap, weights stay behind `Arc`).
    pub fn batch_prototype(&self) -> LifBatchStack {
        LifBatchStack::from_layers(&self.layers)
    }

    /// Advance one timestep with full observability; returns the *final*
    /// layer's trace (hidden layers still advance — Fig. 4 plots output
    /// neurons).
    pub fn step_traced(&mut self, spikes_in: &[bool]) -> StepTrace {
        let n = self.layers.len();
        let mut trace = self.layers[0].step_traced(spikes_in);
        for l in 1..n {
            let fired_prev = std::mem::take(&mut trace.fired);
            trace = self.layers[l].step_traced(&fired_prev);
        }
        trace
    }
}

/// The behavioral inference backend: weights + config, reusable across
/// images (stateless between calls; the per-call stack state is pooled).
#[derive(Debug, Clone)]
pub struct BehavioralNet {
    cfg: SnnConfig,
    stack: LifStack,
}

impl BehavioralNet {
    /// Build from a config and any weight source convertible to a
    /// [`WeightStack`] (a bare [`crate::fixed::WeightMatrix`] becomes the
    /// single-layer chain).
    pub fn new(cfg: SnnConfig, weights: impl Into<WeightStack>) -> Result<Self> {
        let cfg = cfg.validated()?;
        let stack = LifStack::new(&cfg, &weights.into())?;
        Ok(BehavioralNet { cfg, stack })
    }

    pub fn config(&self) -> &SnnConfig {
        &self.cfg
    }

    /// Classify one image with the configured full window.
    pub fn classify(&self, img: &Image, seed: u32) -> Classification {
        self.classify_opts(img, seed, self.cfg.timesteps, EarlyExit::Off)
    }

    /// Classify with an explicit window and early-exit policy.
    pub fn classify_opts(
        &self,
        img: &Image,
        seed: u32,
        timesteps: u32,
        early: EarlyExit,
    ) -> Classification {
        let mut stack = self.stack.clone();
        let (c, _) = run_inference(&self.cfg, &mut stack, None, img, seed, timesteps, early, false);
        c
    }

    /// Classify using a caller-owned stack instance (the pooled serving hot
    /// path: the backend checks a [`LifStack`] out of its worker pool and
    /// reuses its state buffers across requests instead of cloning per
    /// call). Identical dynamics to [`BehavioralNet::classify_opts`] —
    /// `run_inference` resets the stack first.
    pub fn classify_with(
        &self,
        stack: &mut LifStack,
        img: &Image,
        seed: u32,
        timesteps: u32,
        early: EarlyExit,
    ) -> Classification {
        run_inference(&self.cfg, stack, None, img, seed, timesteps, early, false).0
    }

    /// Classify through the event-driven **sparse** sweep: identical loop
    /// to [`BehavioralNet::classify_with`] but each layer step walks only
    /// the CSR-retained synapses of its active inputs. The CSR stack must
    /// match this net's topology (typically `weights.to_csr(threshold)` of
    /// the same stack, so threshold 0 is bit-exact with the dense path —
    /// pinned by `sparse_classify_equals_dense_at_threshold_zero`).
    pub fn classify_sparse_with(
        &self,
        stack: &mut LifStack,
        sparse: &SparseWeightStack,
        img: &Image,
        seed: u32,
        timesteps: u32,
        early: EarlyExit,
    ) -> Result<Classification> {
        sparse.check_topology(&self.cfg.topology)?;
        Ok(run_inference(&self.cfg, stack, Some(sparse), img, seed, timesteps, early, false).0)
    }

    /// A fresh stack instance wired to this net's weights (seed for
    /// instance pools; cheap — weights are shared behind `Arc`).
    pub fn stack_prototype(&self) -> LifStack {
        self.stack.clone()
    }

    /// A fresh batched stack wired to this net's weights (seed for the
    /// batched serving backend's pool).
    pub fn batch_prototype(&self) -> LifBatchStack {
        self.stack.batch_prototype()
    }

    /// Classify a whole sub-batch through **one batched engine pass**:
    /// per timestep, every live image's encoder events are drawn, then
    /// [`LifBatchStack::step_batch`] walks each weight row once for the
    /// batch. Per-image results equal [`BehavioralNet::classify_opts`]
    /// exactly — the per-`(image, seed)` PRNG streams and per-image state
    /// planes commute with batching (pinned by test), and early exit
    /// retires images from the sweep on the same timestep the sequential
    /// loop would stop. Sub-batches are processed in chunks sized by the
    /// topology's calibrated [`crate::plan::ChunkPlan`] (≤
    /// [`LifBatchStack::MAX_LANES`]) so the state planes stay
    /// L2-resident on wide hidden layers.
    pub fn classify_batch_with(
        &self,
        batch: &mut LifBatchStack,
        images: &[&Image],
        seeds: &[u32],
        timesteps: u32,
        early: EarlyExit,
    ) -> Result<Vec<Classification>> {
        if images.len() != seeds.len() {
            return Err(Error::ShapeMismatch(format!(
                "batch of {} images vs {} seeds",
                images.len(),
                seeds.len()
            )));
        }
        let mut out = Vec::with_capacity(images.len());
        let lanes = crate::plan::ChunkPlan::for_topology(&self.cfg.topology).lanes();
        for (imgs, sds) in images.chunks(lanes).zip(seeds.chunks(lanes)) {
            run_batch_inference(&self.cfg, batch, imgs, sds, timesteps, early, &mut out);
        }
        Ok(out)
    }

    /// Classify and capture the full per-step output-layer trace
    /// (Fig. 4 / goldens).
    pub fn classify_traced(
        &self,
        img: &Image,
        seed: u32,
        timesteps: u32,
    ) -> (Classification, Vec<StepTrace>) {
        let mut stack = self.stack.clone();
        run_inference(&self.cfg, &mut stack, None, img, seed, timesteps, EarlyExit::Off, true)
    }
}

/// Shared inference loop. With `sparse` set the event path integrates
/// through the CSR sweep instead of dense rows (trace capture stays
/// dense-only — goldens anchor the traced path).
fn run_inference(
    cfg: &SnnConfig,
    stack: &mut LifStack,
    sparse: Option<&SparseWeightStack>,
    img: &Image,
    seed: u32,
    timesteps: u32,
    early: EarlyExit,
    want_trace: bool,
) -> (Classification, Vec<StepTrace>) {
    stack.reset();
    let early = early.clamped_for(cfg);
    let mut enc = PoissonEncoder::new(img, seed);
    let mut spikes_in = vec![false; cfg.n_inputs()];
    let mut active = Vec::with_capacity(cfg.n_inputs());
    let mut fired = vec![false; cfg.n_outputs()];
    let mut first_spike: Vec<Option<u32>> = vec![None; cfg.n_outputs()];
    let mut traces = Vec::new();
    let mut steps_run = 0u32;

    for t in 0..timesteps {
        if want_trace {
            enc.step_into(&mut spikes_in);
            let trace = stack.step_traced(&spikes_in);
            fired.copy_from_slice(&trace.fired);
            traces.push(trace);
        } else {
            // Fused event-list hot path (perf passes 3+4): the encoder
            // emits spiking indices directly into the integration step.
            enc.step_active_into(&mut active);
            match sparse {
                Some(sp) => stack.step_events_sparse_into(sp, &active, &mut fired),
                None => stack.step_events_into(&active, &mut fired),
            }
        }
        for (j, &f) in fired.iter().enumerate() {
            if f && first_spike[j].is_none() {
                first_spike[j] = Some(t);
            }
        }
        steps_run = t + 1;

        if let EarlyExit::Margin { margin, min_steps } = early {
            // The shared allocation-free predicate (`util::margin_reached`)
            // — the same function the RTL fast path evaluates at the same
            // schedule point, so the two engines cannot drift.
            if steps_run >= min_steps && margin_reached(stack.spike_counts(), margin) {
                break;
            }
        }
    }

    let spike_counts = stack.spike_counts().to_vec();
    let class = Classification::decide(cfg.decision, &spike_counts, &first_spike);
    (
        Classification {
            class,
            spike_counts,
            first_spike,
            steps_run,
            adds_performed: stack.adds_performed(),
        },
        traces,
    )
}

/// Shared batched inference loop (one ≤`MAX_LANES` chunk): the batch-wide
/// mirror of [`run_inference`] — same clamp, same margin predicate at the
/// same schedule point, per image.
fn run_batch_inference(
    cfg: &SnnConfig,
    batch: &mut LifBatchStack,
    images: &[&Image],
    seeds: &[u32],
    timesteps: u32,
    early: EarlyExit,
    out: &mut Vec<Classification>,
) {
    let b_n = images.len();
    batch.reset(b_n);
    let early = early.clamped_for(cfg);
    let mut encoders: Vec<PoissonEncoder> =
        images.iter().zip(seeds).map(|(img, &s)| PoissonEncoder::new(img, s)).collect();
    let mut active: Vec<Vec<u32>> =
        (0..b_n).map(|_| Vec::with_capacity(cfg.n_inputs())).collect();
    let mut live: Vec<usize> = (0..b_n).collect();
    let n_out = cfg.n_outputs();
    let mut first_spike: Vec<Vec<Option<u32>>> = vec![vec![None; n_out]; b_n];
    let mut steps_run = vec![0u32; b_n];
    // Allocation-free margin gather from the neuron-major count plane.
    let mut counts = Vec::with_capacity(n_out);

    for t in 0..timesteps {
        // Each live image draws its own independent Poisson events…
        for &b in &live {
            encoders[b].step_active_into(&mut active[b]);
        }
        // …and one engine pass serves the whole sub-batch.
        batch.step_batch(&live, &active);
        for &b in &live {
            for j in 0..n_out {
                if batch.output_fired(b, j) && first_spike[b][j].is_none() {
                    first_spike[b][j] = Some(t);
                }
            }
            steps_run[b] = t + 1;
        }
        if let EarlyExit::Margin { margin, min_steps } = early {
            if t + 1 >= min_steps {
                live.retain(|&b| {
                    counts.clear();
                    batch.extend_spike_counts(b, &mut counts);
                    !margin_reached(&counts, margin)
                });
            }
        }
        if live.is_empty() {
            break;
        }
    }

    for b in 0..b_n {
        let spike_counts = batch.spike_counts(b);
        let class = Classification::decide(cfg.decision, &spike_counts, &first_spike[b]);
        out.push(Classification {
            class,
            spike_counts,
            first_spike: std::mem::take(&mut first_spike[b]),
            steps_run: steps_run[b],
            adds_performed: batch.adds_performed(b),
        });
    }
}

/// Convenience free function: classify with a fresh net (tests, examples).
pub fn classify(
    cfg: &SnnConfig,
    weights: impl Into<WeightStack>,
    img: &Image,
    seed: u32,
) -> Result<Classification> {
    Ok(BehavioralNet::new(cfg.clone(), weights)?.classify(img, seed))
}

/// Convenience free function with trace capture.
pub fn classify_with_trace(
    cfg: &SnnConfig,
    weights: impl Into<WeightStack>,
    img: &Image,
    seed: u32,
) -> Result<(Classification, Vec<StepTrace>)> {
    Ok(BehavioralNet::new(cfg.clone(), weights)?.classify_traced(img, seed, cfg.timesteps))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DecisionPolicy, PruneMode};
    use crate::data::{Image, IMG_PIXELS};
    use crate::fixed::WeightMatrix;

    /// Weights that make neuron k respond to intensity in "its" block of
    /// pixels: a crisp, controllable classifier for testing readout.
    fn block_weights() -> WeightMatrix {
        let mut w = vec![0i32; 784 * 10];
        for i in 0..784 {
            let block = i / 79; // ~79 pixels per class block
            if block < 10 {
                w[i * 10 + block] = 40;
            }
        }
        WeightMatrix::from_rows(784, 10, 9, w).unwrap()
    }

    fn block_image(class: usize) -> Image {
        let mut px = vec![0u8; IMG_PIXELS];
        for i in 0..784 {
            if i / 79 == class {
                px[i] = 250;
            }
        }
        Image { label: class as u8, pixels: px }
    }

    /// A 784→20→10 stack that routes block k through hidden pair
    /// (2k, 2k+1) into output k: a deep classifier with the same crisp
    /// readout as `block_weights`.
    fn deep_block_stack() -> WeightStack {
        let mut w1 = vec![0i32; 784 * 20];
        for i in 0..784 {
            let block = i / 79;
            if block < 10 {
                w1[i * 20 + 2 * block] = 40;
                w1[i * 20 + 2 * block + 1] = 40;
            }
        }
        let mut w2 = vec![0i32; 20 * 10];
        for h in 0..20 {
            w2[h * 10 + h / 2] = 200;
        }
        WeightStack::from_layers(vec![
            WeightMatrix::from_rows(784, 20, 9, w1).unwrap(),
            WeightMatrix::from_rows(20, 10, 9, w2).unwrap(),
        ])
        .unwrap()
    }

    #[test]
    fn block_classifier_is_correct() {
        let cfg = SnnConfig::paper().with_timesteps(10);
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        for class in 0..10usize {
            let out = net.classify(&block_image(class), 42 + class as u32);
            assert_eq!(out.class as usize, class, "counts {:?}", out.spike_counts);
        }
    }

    #[test]
    fn deep_block_classifier_is_correct() {
        // Two spiking layers end to end: the hidden pair fires on the
        // block's drive, and 200-weight fan-in pushes the output neuron
        // over threshold in the same window.
        let cfg = SnnConfig::paper()
            .with_topology(vec![784, 20, 10])
            .with_timesteps(10)
            .with_prune(PruneMode::Off);
        let net = BehavioralNet::new(cfg, deep_block_stack()).unwrap();
        for class in 0..10usize {
            let out = net.classify(&block_image(class), 42 + class as u32);
            assert_eq!(out.class as usize, class, "counts {:?}", out.spike_counts);
            assert_eq!(out.spike_counts.len(), 10);
        }
    }

    #[test]
    fn stack_rejects_topology_mismatch() {
        let cfg = SnnConfig::paper().with_topology(vec![784, 16, 10]);
        assert!(BehavioralNet::new(cfg, deep_block_stack()).is_err());
        let cfg = SnnConfig::paper(); // [784, 10] vs 2-layer stack
        assert!(BehavioralNet::new(cfg, deep_block_stack()).is_err());
    }

    #[test]
    fn deep_adds_sum_across_layers() {
        let cfg = SnnConfig::paper()
            .with_topology(vec![784, 20, 10])
            .with_timesteps(6)
            .with_prune(PruneMode::Off);
        let net = BehavioralNet::new(cfg, deep_block_stack()).unwrap();
        let out = net.classify(&block_image(2), 5);
        let mut stack = net.stack_prototype();
        let redo = net.classify_with(&mut stack, &block_image(2), 5, 6, EarlyExit::Off);
        assert_eq!(out, redo);
        // Layer-wise accounting must decompose the total.
        let per_layer: u64 = (0..stack.n_layers()).map(|l| stack.layer(l).adds_performed()).sum();
        assert_eq!(per_layer, out.adds_performed);
        assert!(
            stack.layer(0).adds_performed() > 0 && stack.layer(1).adds_performed() > 0,
            "both layers must integrate"
        );
    }

    #[test]
    fn early_exit_stops_sooner_and_agrees() {
        // Pruning caps every spike count at 1, which caps the reachable
        // margin at 1 — disable it so the margin policy can trigger.
        let cfg = SnnConfig::paper().with_timesteps(20).with_prune(PruneMode::Off);
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        let img = block_image(4);
        let full = net.classify_opts(&img, 7, 20, EarlyExit::Off);
        let early = net.classify_opts(&img, 7, 20, EarlyExit::Margin { margin: 3, min_steps: 2 });
        assert_eq!(full.class, early.class);
        assert!(early.steps_run < full.steps_run, "early exit never triggered");
        assert!(early.adds_performed < full.adds_performed);
    }

    #[test]
    fn unreachable_margin_is_clamped_not_ignored() {
        // Bugfix regression: with AfterFires(1) pruning every spike count
        // caps at 1, so margin 3 used to be silently unreachable and the
        // window always ran to completion. The clamp must bring it down
        // to the reachable cap and actually exit early.
        let cfg = SnnConfig::paper()
            .with_timesteps(20)
            .with_prune(PruneMode::AfterFires { after_spikes: 1 });
        let net = BehavioralNet::new(cfg.clone(), block_weights()).unwrap();
        let img = block_image(4);
        let unreachable =
            net.classify_opts(&img, 7, 20, EarlyExit::Margin { margin: 3, min_steps: 2 });
        let capped =
            net.classify_opts(&img, 7, 20, EarlyExit::Margin { margin: 1, min_steps: 2 });
        assert_eq!(
            unreachable, capped,
            "margin above the prune cap must behave exactly like the clamped margin"
        );
        assert!(
            unreachable.steps_run < 20,
            "clamped margin must still exit early (ran {} steps)",
            unreachable.steps_run
        );

        // The clamp itself, unit level: cap follows the *output* layer.
        let clamped = EarlyExit::Margin { margin: 9, min_steps: 0 }.clamped_for(&cfg);
        assert_eq!(clamped, EarlyExit::Margin { margin: 1, min_steps: 0 });
        let unpruned = cfg.clone().with_prune(PruneMode::Off);
        let kept = EarlyExit::Margin { margin: 9, min_steps: 0 }.clamped_for(&unpruned);
        assert_eq!(kept, EarlyExit::Margin { margin: 9, min_steps: 0 });
        assert_eq!(EarlyExit::Off.clamped_for(&cfg), EarlyExit::Off);
    }

    #[test]
    fn per_layer_thresholds_change_behavioral_dynamics() {
        // A deep stack whose readout drive is far below the shared
        // threshold: shared config never fires the output layer, the
        // per-layer override recovers it. (The depth experiment measures
        // the same effect end to end; this pins the behavioral chain.)
        use crate::config::LayerParams;
        let cfg_shared = SnnConfig::paper()
            .with_topology(vec![784, 20, 10])
            .with_timesteps(10)
            .with_v_th(128)
            .with_prune(PruneMode::Off);
        // Readout weights scaled far down: per-step drive is 2 × 6 = 12,
        // whose leak plateau (monotone convergence to 84 = the fixed
        // point of v ← v + 12 − ((v+12)>>3)) can never reach 128 at any
        // window length.
        let mut w1 = vec![0i32; 784 * 20];
        for i in 0..784 {
            let block = i / 79;
            if block < 10 {
                w1[i * 20 + 2 * block] = 40;
                w1[i * 20 + 2 * block + 1] = 40;
            }
        }
        let mut w2 = vec![0i32; 20 * 10];
        for h in 0..20 {
            w2[h * 10 + h / 2] = 6;
        }
        let stack = WeightStack::from_layers(vec![
            WeightMatrix::from_rows(784, 20, 9, w1).unwrap(),
            WeightMatrix::from_rows(20, 10, 9, w2).unwrap(),
        ])
        .unwrap();
        let shared = BehavioralNet::new(cfg_shared.clone(), stack.clone()).unwrap();
        let out = shared.classify(&block_image(6), 3);
        assert_eq!(
            out.spike_counts.iter().sum::<u32>(),
            0,
            "shared threshold must starve the readout for this stack"
        );
        let cfg_cal = cfg_shared
            .with_layer_params(vec![LayerParams::default(), LayerParams::with_v_th(30)])
            .validated()
            .unwrap();
        let calibrated = BehavioralNet::new(cfg_cal, stack).unwrap();
        let out = calibrated.classify(&block_image(6), 3);
        assert_eq!(out.class, 6, "calibrated readout threshold recovers the class");
        assert!(out.spike_counts[6] > 0);
    }

    #[test]
    fn first_spike_policy_falls_back_to_counts() {
        // Zero weights → nobody ever fires → FirstSpike must fall back.
        let cfg = SnnConfig::paper().with_decision(DecisionPolicy::FirstSpike).with_timesteps(3);
        let w = WeightMatrix::zeros(784, 10, 9);
        let net = BehavioralNet::new(cfg, w).unwrap();
        let out = net.classify(&block_image(2), 1);
        assert_eq!(out.class, 0, "all-zero counts must tie-break to class 0");
        assert!(out.first_spike.iter().all(Option::is_none));
    }

    #[test]
    fn first_spike_policy_picks_earliest() {
        let cfg = SnnConfig::paper()
            .with_decision(DecisionPolicy::FirstSpike)
            .with_timesteps(20)
            .with_prune(PruneMode::Off);
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        let img = block_image(6);
        let out = net.classify(&img, 9);
        assert_eq!(out.class, 6);
        let t6 = out.first_spike[6].expect("neuron 6 must fire");
        for (j, fs) in out.first_spike.iter().enumerate() {
            if let Some(t) = fs {
                assert!(*t >= t6, "neuron {j} fired before the target class");
            }
        }
    }

    #[test]
    fn trace_length_matches_window() {
        let cfg = SnnConfig::paper();
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        let (out, traces) = net.classify_traced(&block_image(1), 3, 12);
        assert_eq!(traces.len(), 12);
        assert_eq!(out.steps_run, 12);
    }

    #[test]
    fn deep_traced_matches_event_path() {
        // The traced path (boolean relay) and the event-list path (index
        // relay) must produce identical final-layer outcomes at depth 2.
        let cfg = SnnConfig::paper()
            .with_topology(vec![784, 20, 10])
            .with_timesteps(8)
            .with_prune(PruneMode::Off);
        let net = BehavioralNet::new(cfg.clone(), deep_block_stack()).unwrap();
        for class in [0usize, 3, 9] {
            let img = block_image(class);
            let fast = net.classify_opts(&img, 11, 8, EarlyExit::Off);
            let (traced, traces) = net.classify_traced(&img, 11, 8);
            assert_eq!(fast, traced, "paths diverge for class {class}");
            assert_eq!(traces.len(), 8);
            // Per-step fired flags must agree with the first-spike record.
            for (j, fs) in traced.first_spike.iter().enumerate() {
                if let Some(t) = fs {
                    assert!(traces[*t as usize].fired[j]);
                }
            }
        }
    }

    /// The behavioral batch theorem: `classify_batch_with` equals
    /// `classify_opts` image for image — full `Classification` equality,
    /// including `first_spike`, `steps_run` and `adds_performed` — across
    /// batch sizes, depths, per-layer overrides, and early-exit on/off,
    /// with one reused batch state across all calls (pinning reset too).
    #[test]
    fn batched_inference_equals_sequential() {
        use crate::config::LayerParams;
        let mut rng = crate::prng::Xorshift32::new(0xBEE5);
        let configs: Vec<(SnnConfig, WeightStack)> = vec![
            (
                SnnConfig::paper().with_timesteps(6).with_prune(PruneMode::Off),
                WeightStack::from(block_weights()),
            ),
            (
                SnnConfig::paper()
                    .with_topology(vec![784, 20, 10])
                    .with_timesteps(6)
                    .with_prune(PruneMode::Off),
                deep_block_stack(),
            ),
            (
                // Heterogeneous per-layer thresholds + readout pruning:
                // the per-layer resolution must batch identically, and
                // the margin clamp must bite identically in both paths.
                SnnConfig::paper()
                    .with_topology(vec![784, 20, 10])
                    .with_timesteps(6)
                    .with_prune(PruneMode::Off)
                    .with_layer_params(vec![
                        LayerParams::default(),
                        LayerParams {
                            v_th: Some(100),
                            decay_shift: Some(2),
                            prune: Some(PruneMode::AfterFires { after_spikes: 1 }),
                        },
                    ]),
                deep_block_stack(),
            ),
        ];
        for (cfg, stack) in configs {
            let net = BehavioralNet::new(cfg, stack).unwrap();
            let mut batch_state = net.batch_prototype();
            // 67 lanes crosses the mask-word boundary: one multi-word
            // chunk at the widened `MAX_LANES`, lanes 64+ in word 1.
            for batch in [1usize, 2, 5, 9, 67] {
                for early in
                    [EarlyExit::Off, EarlyExit::Margin { margin: 2, min_steps: 2 }]
                {
                    let images: Vec<Image> =
                        (0..batch).map(|i| block_image((i * 3 + batch) % 10)).collect();
                    let refs: Vec<&Image> = images.iter().collect();
                    let seeds: Vec<u32> = (0..batch).map(|_| rng.next_u32()).collect();
                    let got = net
                        .classify_batch_with(&mut batch_state, &refs, &seeds, 6, early)
                        .unwrap();
                    assert_eq!(got.len(), batch);
                    for (i, g) in got.iter().enumerate() {
                        let want = net.classify_opts(&images[i], seeds[i], 6, early);
                        assert_eq!(g, &want, "lane {i} (batch={batch}, early={early:?})");
                    }
                }
            }
        }

        // Length mismatch is an error, not a panic (contract parity with
        // `RtlCore::run_fast_batch`).
        let net = BehavioralNet::new(SnnConfig::paper().with_timesteps(2), block_weights())
            .unwrap();
        let mut bs = net.batch_prototype();
        let img = block_image(1);
        assert!(net
            .classify_batch_with(&mut bs, &[&img, &img], &[1], 2, EarlyExit::Off)
            .is_err());
    }

    /// Behavioral sparse theorem: at threshold 0 the CSR sweep equals the
    /// dense event path in full `Classification` (including
    /// `adds_performed`); above it, it equals the dense path run over the
    /// pruned re-densification (zero-weight adds are state-neutral), with
    /// adds weakly lower.
    #[test]
    fn sparse_classify_equals_dense_at_threshold_zero() {
        use crate::config::LayerParams;
        let configs: Vec<(SnnConfig, WeightStack)> = vec![
            (
                SnnConfig::paper().with_timesteps(8).with_prune(PruneMode::Off),
                WeightStack::from(block_weights()),
            ),
            (
                SnnConfig::paper()
                    .with_topology(vec![784, 20, 10])
                    .with_timesteps(8)
                    .with_prune(PruneMode::Off)
                    .with_layer_params(vec![
                        LayerParams::default(),
                        LayerParams {
                            v_th: Some(100),
                            decay_shift: Some(2),
                            prune: Some(PruneMode::AfterFires { after_spikes: 1 }),
                        },
                    ]),
                deep_block_stack(),
            ),
        ];
        for (cfg, stack) in configs {
            let net = BehavioralNet::new(cfg.clone(), stack.clone()).unwrap();
            let mut pooled = net.stack_prototype();
            let csr0 = stack.to_csr(0);
            for (i, early) in [EarlyExit::Off, EarlyExit::Margin { margin: 2, min_steps: 2 }]
                .into_iter()
                .enumerate()
            {
                let img = block_image((3 + i) % 10);
                let seed = 90 + i as u32;
                let dense = net.classify_opts(&img, seed, 8, early);
                let got = net
                    .classify_sparse_with(&mut pooled, &csr0, &img, seed, 8, early)
                    .unwrap();
                assert_eq!(got, dense, "threshold-0 sparse diverged (early={early:?})");

                // Heavy magnitude pruning vs the pruned-dense reference.
                let threshold = 35;
                let csr_t = stack.to_csr(threshold);
                let pruned_net =
                    BehavioralNet::new(cfg.clone(), csr_t.to_dense()).unwrap();
                let want = pruned_net.classify_opts(&img, seed, 8, early);
                let got = net
                    .classify_sparse_with(&mut pooled, &csr_t, &img, seed, 8, early)
                    .unwrap();
                assert_eq!(got.class, want.class);
                assert_eq!(got.spike_counts, want.spike_counts);
                assert_eq!(got.first_spike, want.first_spike);
                assert_eq!(got.steps_run, want.steps_run);
                assert!(got.adds_performed <= want.adds_performed);
            }
        }

        // Topology mismatch is a typed error.
        let net = BehavioralNet::new(
            SnnConfig::paper().with_timesteps(2),
            block_weights(),
        )
        .unwrap();
        let mut pooled = net.stack_prototype();
        let wrong = deep_block_stack().to_csr(0);
        assert!(net
            .classify_sparse_with(&mut pooled, &wrong, &block_image(0), 1, 2, EarlyExit::Off)
            .is_err());
    }

    #[test]
    fn pooled_stack_reuse_matches_fresh_clone() {
        // A single reused stack instance must produce identical results to
        // per-call clones, including straight after early-exit runs that
        // leave partial state behind.
        let cfg = SnnConfig::paper().with_timesteps(12).with_prune(PruneMode::Off);
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        let mut pooled = net.stack_prototype();
        for i in 0..12u32 {
            let img = block_image((i % 10) as usize);
            let early = if i % 2 == 0 {
                EarlyExit::Off
            } else {
                EarlyExit::Margin { margin: 2, min_steps: 2 }
            };
            let fresh = net.classify_opts(&img, 40 + i, 12, early);
            let reused = net.classify_with(&mut pooled, &img, 40 + i, 12, early);
            assert_eq!(fresh, reused, "request {i}");
        }
    }

    #[test]
    fn deterministic_across_calls() {
        let cfg = SnnConfig::paper().with_timesteps(6);
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        let img = block_image(8);
        let a = net.classify(&img, 5);
        let b = net.classify(&img, 5);
        assert_eq!(a, b);
    }
}
