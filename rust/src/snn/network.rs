//! Full behavioral inference: encoder + LIF layer + readout policies.

use crate::config::{DecisionPolicy, SnnConfig};
use crate::data::Image;
use crate::error::Result;
use crate::fixed::WeightMatrix;
use crate::snn::{LifLayer, PoissonEncoder, StepTrace};
use crate::util::priority_argmax;

/// Early-termination policy applied between timesteps (the serving-level
/// generalization of the paper's active-pruning idea: stop paying for
/// timesteps once the decision is confident).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EarlyExit {
    /// Run the full window.
    Off,
    /// Stop once the leading class's spike count exceeds the runner-up by
    /// `margin` *and* at least `min_steps` have run.
    ///
    /// Note the interaction with neuron-level pruning: with the paper's
    /// `PruneMode::AfterFires { after_spikes: 1 }` every spike count is
    /// capped at 1, so the reachable margin is 1. Use `margin: 1` with
    /// pruning on, or disable pruning for larger margins.
    Margin { margin: u32, min_steps: u32 },
}

/// Inference result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Classification {
    /// Predicted class.
    pub class: u8,
    /// Output spike counts per class over the executed window.
    pub spike_counts: Vec<u32>,
    /// Timestep at which each neuron first fired (`None` = never).
    pub first_spike: Vec<Option<u32>>,
    /// Timesteps actually executed (< window when early exit triggers).
    pub steps_run: u32,
    /// Integrate-adds actually performed (sparsity accounting).
    pub adds_performed: u64,
}

impl Classification {
    /// Decide a class from spike evidence under `policy`. Ties break toward
    /// the lowest class index — the behaviour of a hardware priority
    /// encoder scanning `spike_reg[0..9]`.
    fn decide(
        policy: DecisionPolicy,
        spike_counts: &[u32],
        first_spike: &[Option<u32>],
    ) -> u8 {
        match policy {
            DecisionPolicy::SpikeCount => priority_argmax(spike_counts) as u8,
            DecisionPolicy::FirstSpike => {
                let mut best: Option<(u32, usize)> = None;
                for (j, fs) in first_spike.iter().enumerate() {
                    if let Some(t) = fs {
                        if best.map_or(true, |(bt, _)| *t < bt) {
                            best = Some((*t, j));
                        }
                    }
                }
                match best {
                    Some((_, j)) => j as u8,
                    None => priority_argmax(spike_counts) as u8,
                }
            }
        }
    }
}

/// The behavioral inference backend: weights + config, reusable across
/// images (stateless between calls; the per-call layer state is pooled).
#[derive(Debug, Clone)]
pub struct BehavioralNet {
    cfg: SnnConfig,
    layer: LifLayer,
}

impl BehavioralNet {
    pub fn new(cfg: SnnConfig, weights: WeightMatrix) -> Result<Self> {
        let cfg = cfg.validated()?;
        let layer = LifLayer::new(cfg.clone(), &weights)?;
        Ok(BehavioralNet { cfg, layer })
    }

    pub fn config(&self) -> &SnnConfig {
        &self.cfg
    }

    /// Classify one image with the configured full window.
    pub fn classify(&self, img: &Image, seed: u32) -> Classification {
        self.classify_opts(img, seed, self.cfg.timesteps, EarlyExit::Off)
    }

    /// Classify with an explicit window and early-exit policy.
    pub fn classify_opts(
        &self,
        img: &Image,
        seed: u32,
        timesteps: u32,
        early: EarlyExit,
    ) -> Classification {
        let mut layer = self.layer.clone();
        let (c, _) = run_inference(&self.cfg, &mut layer, img, seed, timesteps, early, false);
        c
    }

    /// Classify using a caller-owned layer instance (the pooled serving hot
    /// path: the backend checks a [`LifLayer`] out of its worker pool and
    /// reuses its state buffers across requests instead of cloning per
    /// call). Identical dynamics to [`BehavioralNet::classify_opts`] —
    /// `run_inference` resets the layer first.
    pub fn classify_with(
        &self,
        layer: &mut LifLayer,
        img: &Image,
        seed: u32,
        timesteps: u32,
        early: EarlyExit,
    ) -> Classification {
        run_inference(&self.cfg, layer, img, seed, timesteps, early, false).0
    }

    /// A fresh layer instance wired to this net's weights (seed for
    /// instance pools; cheap — weights are shared behind `Arc`).
    pub fn layer_prototype(&self) -> LifLayer {
        self.layer.clone()
    }

    /// Classify and capture the full per-step trace (Fig. 4 / goldens).
    pub fn classify_traced(
        &self,
        img: &Image,
        seed: u32,
        timesteps: u32,
    ) -> (Classification, Vec<StepTrace>) {
        let mut layer = self.layer.clone();
        run_inference(&self.cfg, &mut layer, img, seed, timesteps, EarlyExit::Off, true)
    }
}

/// Shared inference loop.
fn run_inference(
    cfg: &SnnConfig,
    layer: &mut LifLayer,
    img: &Image,
    seed: u32,
    timesteps: u32,
    early: EarlyExit,
    want_trace: bool,
) -> (Classification, Vec<StepTrace>) {
    layer.reset();
    let mut enc = PoissonEncoder::new(img, seed);
    let mut spikes_in = vec![false; cfg.n_inputs];
    let mut active = Vec::with_capacity(cfg.n_inputs);
    let mut fired = vec![false; cfg.n_outputs];
    let mut first_spike: Vec<Option<u32>> = vec![None; cfg.n_outputs];
    let mut traces = Vec::new();
    let mut steps_run = 0u32;

    for t in 0..timesteps {
        if want_trace {
            enc.step_into(&mut spikes_in);
            let trace = layer.step_traced(&spikes_in);
            fired.copy_from_slice(&trace.fired);
            traces.push(trace);
        } else {
            // Fused event-list hot path (perf passes 3+4): the encoder
            // emits spiking indices directly into the integration step.
            enc.step_active_into(&mut active);
            layer.step_events_into(&active, &mut fired);
        }
        for (j, &f) in fired.iter().enumerate() {
            if f && first_spike[j].is_none() {
                first_spike[j] = Some(t);
            }
        }
        steps_run = t + 1;

        if let EarlyExit::Margin { margin, min_steps } = early {
            if steps_run >= min_steps {
                let counts = layer.spike_counts();
                let mut sorted: Vec<u32> = counts.to_vec();
                sorted.sort_unstable_by(|a, b| b.cmp(a));
                if sorted[0] >= sorted[1] + margin {
                    break;
                }
            }
        }
    }

    let spike_counts = layer.spike_counts().to_vec();
    let class = Classification::decide(cfg.decision, &spike_counts, &first_spike);
    (
        Classification {
            class,
            spike_counts,
            first_spike,
            steps_run,
            adds_performed: layer.adds_performed(),
        },
        traces,
    )
}

/// Convenience free function: classify with a fresh net (tests, examples).
pub fn classify(cfg: &SnnConfig, weights: &WeightMatrix, img: &Image, seed: u32) -> Result<Classification> {
    Ok(BehavioralNet::new(cfg.clone(), weights.clone())?.classify(img, seed))
}

/// Convenience free function with trace capture.
pub fn classify_with_trace(
    cfg: &SnnConfig,
    weights: &WeightMatrix,
    img: &Image,
    seed: u32,
) -> Result<(Classification, Vec<StepTrace>)> {
    Ok(BehavioralNet::new(cfg.clone(), weights.clone())?.classify_traced(img, seed, cfg.timesteps))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DecisionPolicy, PruneMode};
    use crate::data::{Image, IMG_PIXELS};

    /// Weights that make neuron k respond to intensity in "its" block of
    /// pixels: a crisp, controllable classifier for testing readout.
    fn block_weights() -> WeightMatrix {
        let mut w = vec![0i32; 784 * 10];
        for i in 0..784 {
            let block = i / 79; // ~79 pixels per class block
            if block < 10 {
                w[i * 10 + block] = 40;
            }
        }
        WeightMatrix::from_rows(784, 10, 9, w).unwrap()
    }

    fn block_image(class: usize) -> Image {
        let mut px = vec![0u8; IMG_PIXELS];
        for i in 0..784 {
            if i / 79 == class {
                px[i] = 250;
            }
        }
        Image { label: class as u8, pixels: px }
    }

    #[test]
    fn block_classifier_is_correct() {
        let cfg = SnnConfig::paper().with_timesteps(10);
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        for class in 0..10usize {
            let out = net.classify(&block_image(class), 42 + class as u32);
            assert_eq!(out.class as usize, class, "counts {:?}", out.spike_counts);
        }
    }

    #[test]
    fn early_exit_stops_sooner_and_agrees() {
        // Pruning caps every spike count at 1, which caps the reachable
        // margin at 1 — disable it so the margin policy can trigger.
        let cfg = SnnConfig::paper().with_timesteps(20).with_prune(PruneMode::Off);
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        let img = block_image(4);
        let full = net.classify_opts(&img, 7, 20, EarlyExit::Off);
        let early = net.classify_opts(&img, 7, 20, EarlyExit::Margin { margin: 3, min_steps: 2 });
        assert_eq!(full.class, early.class);
        assert!(early.steps_run < full.steps_run, "early exit never triggered");
        assert!(early.adds_performed < full.adds_performed);
    }

    #[test]
    fn first_spike_policy_falls_back_to_counts() {
        // Zero weights → nobody ever fires → FirstSpike must fall back.
        let cfg = SnnConfig::paper().with_decision(DecisionPolicy::FirstSpike).with_timesteps(3);
        let w = WeightMatrix::zeros(784, 10, 9);
        let net = BehavioralNet::new(cfg, w).unwrap();
        let out = net.classify(&block_image(2), 1);
        assert_eq!(out.class, 0, "all-zero counts must tie-break to class 0");
        assert!(out.first_spike.iter().all(Option::is_none));
    }

    #[test]
    fn first_spike_policy_picks_earliest() {
        let cfg = SnnConfig::paper()
            .with_decision(DecisionPolicy::FirstSpike)
            .with_timesteps(20)
            .with_prune(PruneMode::Off);
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        let img = block_image(6);
        let out = net.classify(&img, 9);
        assert_eq!(out.class, 6);
        let t6 = out.first_spike[6].expect("neuron 6 must fire");
        for (j, fs) in out.first_spike.iter().enumerate() {
            if let Some(t) = fs {
                assert!(*t >= t6, "neuron {j} fired before the target class");
            }
        }
    }

    #[test]
    fn trace_length_matches_window() {
        let cfg = SnnConfig::paper();
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        let (out, traces) = net.classify_traced(&block_image(1), 3, 12);
        assert_eq!(traces.len(), 12);
        assert_eq!(out.steps_run, 12);
    }

    #[test]
    fn pooled_layer_reuse_matches_fresh_clone() {
        // A single reused layer instance must produce identical results to
        // per-call clones, including straight after early-exit runs that
        // leave partial state behind.
        let cfg = SnnConfig::paper().with_timesteps(12).with_prune(PruneMode::Off);
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        let mut pooled = net.layer_prototype();
        for i in 0..12u32 {
            let img = block_image((i % 10) as usize);
            let early = if i % 2 == 0 {
                EarlyExit::Off
            } else {
                EarlyExit::Margin { margin: 2, min_steps: 2 }
            };
            let fresh = net.classify_opts(&img, 40 + i, 12, early);
            let reused = net.classify_with(&mut pooled, &img, 40 + i, 12, early);
            assert_eq!(fresh, reused, "request {i}");
        }
    }

    #[test]
    fn deterministic_across_calls() {
        let cfg = SnnConfig::paper().with_timesteps(6);
        let net = BehavioralNet::new(cfg, block_weights()).unwrap();
        let img = block_image(8);
        let a = net.classify(&img, 5);
        let b = net.classify(&img, 5);
        assert_eq!(a, b);
    }
}
