//! Minimal in-repo property-testing support.
//!
//! `proptest` is not part of the offline crate set this repository builds
//! against, so this module provides the slice of it the test-suite needs:
//! seeded random case generation with a failure report that prints the
//! case index and the generator seed needed to replay a failure
//! deterministically.
//!
//! Usage (`no_run`: doctest binaries miss the xla rpath in this image):
//!
//! ```no_run
//! use snn_rtl::testutil::PropRunner;
//! PropRunner::new("my_invariant", 500).run(|g| {
//!     let x = g.rng.range_i32(-10, 10);
//!     assert!(x >= -10 && x <= 10);
//! });
//! ```

use crate::prng::Xorshift32;

/// Per-case generation context handed to the property closure.
pub struct Gen {
    /// Seeded PRNG for drawing case data.
    pub rng: Xorshift32,
    /// Index of the case within the run (0-based).
    pub case: u32,
}

impl Gen {
    /// Draw a vector of `len` bytes.
    pub fn bytes(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| (self.rng.next_u32() & 0xFF) as u8).collect()
    }

    /// Draw a vector of `len` i32 values in `[lo, hi]`.
    pub fn vec_i32(&mut self, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..len).map(|_| self.rng.range_i32(lo, hi)).collect()
    }

    /// Draw one of the provided choices by reference.
    pub fn choice<'a, T>(&mut self, options: &'a [T]) -> &'a T {
        assert!(!options.is_empty());
        &options[self.rng.below(options.len() as u32) as usize]
    }
}

/// A seeded property-test runner.
pub struct PropRunner {
    name: &'static str,
    cases: u32,
    seed: u32,
}

impl PropRunner {
    /// Create a runner executing `cases` random cases. The seed is derived
    /// from the property name so independent properties draw independent
    /// case streams, while every CI run is reproducible. Override with
    /// `SNN_PROP_SEED` to replay a failure.
    pub fn new(name: &'static str, cases: u32) -> Self {
        let seed = match std::env::var("SNN_PROP_SEED") {
            Ok(s) => s.parse().expect("SNN_PROP_SEED must be a u32"),
            Err(_) => name.bytes().fold(0x811C_9DC5u32, |h, b| {
                (h ^ u32::from(b)).wrapping_mul(0x0100_0193) // FNV-1a
            }),
        };
        PropRunner { name, cases, seed }
    }

    /// Run the property across all cases. Panics (with replay info) on the
    /// first failing case.
    pub fn run<F: FnMut(&mut Gen)>(self, mut property: F) {
        // Under Miri every instruction is interpreted (~2–3 orders of
        // magnitude slower), so shrink the default case count and keep the
        // run a smoke test; SNN_PROP_CASES still overrides explicitly.
        let cases = match std::env::var("SNN_PROP_CASES") {
            Ok(s) => s.parse().expect("SNN_PROP_CASES must be a u32"),
            Err(_) if cfg!(miri) => (self.cases / 25).max(2),
            Err(_) => self.cases,
        };
        for case in 0..cases {
            let case_seed = self.seed.wrapping_add(case.wrapping_mul(0x9E37_79B9));
            let mut g = Gen { rng: Xorshift32::new(case_seed), case };
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                property(&mut g);
            }));
            if let Err(payload) = outcome {
                let msg = payload
                    .downcast_ref::<String>()
                    .map(String::as_str)
                    .or_else(|| payload.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                panic!(
                    "property '{}' failed at case {case}/{cases} \
                     (replay with SNN_PROP_SEED={} SNN_PROP_CASES={}): {msg}",
                    self.name,
                    self.seed,
                    case + 1,
                );
            }
        }
    }
}

/// Assert two slices are equal, reporting the first differing index —
/// far more readable than `assert_eq!` on large golden traces.
pub fn assert_slices_eq<T: PartialEq + std::fmt::Debug>(a: &[T], b: &[T], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x, y, "{what}: first mismatch at index {i}: {x:?} vs {y:?}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_is_deterministic() {
        let mut first: Vec<i32> = Vec::new();
        PropRunner::new("determinism_probe", 10).run(|g| {
            first.push(g.rng.range_i32(0, 1000));
        });
        let mut second: Vec<i32> = Vec::new();
        PropRunner::new("determinism_probe", 10).run(|g| {
            second.push(g.rng.range_i32(0, 1000));
        });
        assert_eq!(first, second);
    }

    #[test]
    fn different_properties_draw_different_streams() {
        let mut a = Vec::new();
        PropRunner::new("stream_a", 5).run(|g| a.push(g.rng.next_u32()));
        let mut b = Vec::new();
        PropRunner::new("stream_b", 5).run(|g| b.push(g.rng.next_u32()));
        assert_ne!(a, b);
    }

    #[test]
    #[should_panic(expected = "replay with SNN_PROP_SEED=")]
    fn failure_reports_replay_seed() {
        PropRunner::new("always_fails", 3).run(|g| {
            assert!(g.case < 1, "boom");
        });
    }

    #[test]
    fn gen_helpers_in_range() {
        PropRunner::new("gen_helpers", 50).run(|g| {
            let bs = g.bytes(16);
            assert_eq!(bs.len(), 16);
            let vs = g.vec_i32(8, -5, 5);
            assert!(vs.iter().all(|v| (-5..=5).contains(v)));
            let c = *g.choice(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&c));
        });
    }
}
