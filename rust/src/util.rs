//! Small helpers shared across layers.

use std::sync::{Mutex, MutexGuard};

/// Poison-recovering lock for state that stays sound across a panic
/// (counter sinks, recycled-instance stashes, fault bookkeeping). A
/// `PoisonError` only means *some* thread panicked while holding the
/// guard; for these uses the data is still meaningful, and propagating
/// the panic would cascade one fault through every subsequent request.
///
/// This is the **only** place in the repo allowed to call
/// `Mutex::lock` without routing the poison case somewhere deliberate —
/// `pallas-lint` rule L1 rejects `.lock().unwrap()` / `.lock().expect(`
/// everywhere else, so every mutex acquisition either goes through here
/// or handles `PoisonError` explicitly at the call site.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Priority-encoded argmax: the index of the maximum value, ties broken
/// toward the **lowest** index — the behaviour of a hardware priority
/// encoder scanning the spike-count registers from 0 upward.
///
/// This is the one argmax every readout path uses (the RTL controller, the
/// behavioral network and the coordinator backends), so the tie-breaking
/// contract is defined — and tested — exactly once.
///
/// Returns 0 for an empty slice (the encoder's all-zero default).
#[inline]
pub fn priority_argmax(xs: &[u32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Allocation-free top-2 scan: the largest and second-largest values of
/// `xs` (duplicates count twice: `[5, 5]` → `(5, 5)`). `None` when the
/// slice has no runner-up. One pass, no clone, no sort — this replaces the
/// per-timestep `to_vec` + `sort_unstable` the early-exit margin checks
/// used to pay on every step of every inference.
#[inline]
pub fn top2(xs: &[u32]) -> Option<(u32, u32)> {
    if xs.len() < 2 {
        return None;
    }
    let (mut best, mut second) =
        if xs[0] >= xs[1] { (xs[0], xs[1]) } else { (xs[1], xs[0]) };
    for &x in &xs[2..] {
        if x > best {
            second = best;
            best = x;
        } else if x > second {
            second = x;
        }
    }
    Some((best, second))
}

/// The one early-exit margin predicate shared by the behavioral model, the
/// RTL fast path and the XLA chunk loop: true when the leading spike count
/// beats the runner-up by at least `margin`. A margin needs a runner-up,
/// so degenerate single-output slices are never confident. Keeping this in
/// one place means the schedule points cannot drift apart.
#[inline]
pub fn margin_reached(counts: &[u32], margin: u32) -> bool {
    match top2(counts) {
        Some((best, second)) => best >= second.saturating_add(margin),
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top2_matches_sorted_reference() {
        let cases: &[&[u32]] = &[
            &[3, 1, 4, 1, 5],
            &[5, 5],
            &[0, 0, 0],
            &[9, 1],
            &[1, 9],
            &[2, 7, 7, 3],
            &[u32::MAX, 1, u32::MAX],
        ];
        for xs in cases {
            let mut sorted = xs.to_vec();
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            assert_eq!(top2(xs), Some((sorted[0], sorted[1])), "{xs:?}");
        }
        assert_eq!(top2(&[]), None);
        assert_eq!(top2(&[7]), None);
    }

    #[test]
    fn margin_predicate() {
        assert!(margin_reached(&[5, 2, 0], 3));
        assert!(!margin_reached(&[5, 3, 0], 3));
        assert!(margin_reached(&[0, 4, 1], 3));
        // No runner-up: never confident.
        assert!(!margin_reached(&[9], 1));
        assert!(!margin_reached(&[], 1));
        // Saturating arithmetic near the top of the range.
        assert!(!margin_reached(&[u32::MAX, u32::MAX], 1));
        assert!(margin_reached(&[u32::MAX, 0], u32::MAX));
    }

    #[test]
    fn picks_the_maximum() {
        assert_eq!(priority_argmax(&[0, 2, 5, 1]), 2);
        assert_eq!(priority_argmax(&[9]), 0);
    }

    #[test]
    fn ties_break_to_lowest_index() {
        assert_eq!(priority_argmax(&[0, 0, 0]), 0);
        assert_eq!(priority_argmax(&[1, 3, 3]), 1);
        assert_eq!(priority_argmax(&[0, 2, 5, 5]), 2);
        assert_eq!(priority_argmax(&[7, 0, 7]), 0);
    }

    #[test]
    fn empty_defaults_to_zero() {
        assert_eq!(priority_argmax(&[]), 0);
    }

    #[test]
    fn lock_recover_heals_poison() {
        use std::sync::Arc;
        let m = Arc::new(Mutex::new(41u32));
        let m2 = Arc::clone(&m);
        let t = std::thread::spawn(move || {
            // pallas-lint: lock(util.poison_probe)
            let _g = m2.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            panic!("poison the mutex");
            // pallas-lint: end-lock(util.poison_probe)
        });
        assert!(t.join().is_err());
        // The data survives the panic and stays usable.
        // pallas-lint: lock(util.poison_probe)
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 42);
        // pallas-lint: end-lock(util.poison_probe)
    }
}
