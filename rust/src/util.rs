//! Small helpers shared across layers.

/// Priority-encoded argmax: the index of the maximum value, ties broken
/// toward the **lowest** index — the behaviour of a hardware priority
/// encoder scanning the spike-count registers from 0 upward.
///
/// This is the one argmax every readout path uses (the RTL controller, the
/// behavioral network and the coordinator backends), so the tie-breaking
/// contract is defined — and tested — exactly once.
///
/// Returns 0 for an empty slice (the encoder's all-zero default).
#[inline]
pub fn priority_argmax(xs: &[u32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_the_maximum() {
        assert_eq!(priority_argmax(&[0, 2, 5, 1]), 2);
        assert_eq!(priority_argmax(&[9]), 0);
    }

    #[test]
    fn ties_break_to_lowest_index() {
        assert_eq!(priority_argmax(&[0, 0, 0]), 0);
        assert_eq!(priority_argmax(&[1, 3, 3]), 1);
        assert_eq!(priority_argmax(&[0, 2, 5, 5]), 2);
        assert_eq!(priority_argmax(&[7, 0, 7]), 0);
    }

    #[test]
    fn empty_defaults_to_zero() {
        assert_eq!(priority_argmax(&[]), 0);
    }
}
