//! Shared helpers for the integration suites.

use std::path::PathBuf;

/// The canonical artifacts directory, or `None` when `make artifacts` has
/// not run (tests then skip — the Makefile orders artifacts before tests,
/// so CI always exercises the full suites).
pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipped: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Little-endian binary reader over a byte buffer.
pub struct Cursor<'a> {
    pub buf: &'a [u8],
    pub pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }
    pub fn bytes(&mut self, n: usize) -> &'a [u8] {
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        s
    }
    pub fn u32(&mut self) -> u32 {
        u32::from_le_bytes(self.bytes(4).try_into().unwrap())
    }
    pub fn i32(&mut self) -> i32 {
        i32::from_le_bytes(self.bytes(4).try_into().unwrap())
    }
}
