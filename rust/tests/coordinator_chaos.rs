//! Deterministic chaos suite for the fault-tolerant coordinator: a seeded
//! [`FaultInjectingBackend`] schedule drives panics, transient errors,
//! wrong-length replies and latency spikes through the full serving path,
//! and the assertions are exact — victims are enumerated from the plan up
//! front, never sampled. Invariants pinned here:
//!
//! * every accepted request gets **exactly one** terminal reply (no
//!   duplicates), under mixed faults, under batching and fan-out, and
//!   after the supervisor's restart budget runs out;
//! * recovered requests (transient error / wrong-length, absorbed by the
//!   one retry) are **bit-exact** with a fault-free run of the same
//!   (image, seed);
//! * hard panic victims surface as typed `BackendPanicked` errors, and
//!   each panicked batch costs exactly one worker death plus one
//!   supervised respawn;
//! * engine pools quarantine instances that were checked out across a
//!   panic, and never shrink below their configured capacity.
//!
//! Everything runs under a watchdog so a regression is a failure, never a
//! hung CI job.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use snn_rtl::coordinator::{
    Backend, BackendOutput, BatchPolicy, Coordinator, CoordinatorConfig, FanoutPolicy,
    FaultInjectingBackend, FaultKind, FaultPlan, InstancePool, Request, Response, RtlBackend,
    SupervisionPolicy,
};
use snn_rtl::data::{DigitGen, Image, IMG_PIXELS};
use snn_rtl::error::Error;
use snn_rtl::fixed::WeightMatrix;
use snn_rtl::prng::splitmix32;
use snn_rtl::snn::EarlyExit;
use snn_rtl::util::lock_recover;
use snn_rtl::SnnConfig;

/// Run `body` on a helper thread and fail loudly if it does not finish
/// within `limit` — the chaos suite's hang detector.
fn with_watchdog<F: FnOnce() + Send + 'static>(limit: Duration, body: F) {
    let (done_tx, done_rx) = mpsc::channel();
    let runner = std::thread::spawn(move || {
        body();
        let _ = done_tx.send(());
    });
    match done_rx.recv_timeout(limit) {
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(payload) = runner.join() {
                std::panic::resume_unwind(payload);
            }
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("watchdog: test exceeded {limit:?} — likely a hang/deadlock")
        }
    }
}

/// Block-diagonal weights: pixel block `k` feeds output `k`, so real
/// engines produce crisp, reproducible classifications.
fn test_weights() -> WeightMatrix {
    let mut w = vec![0i32; 784 * 10];
    for i in 0..784 {
        let block = i / 79;
        if block < 10 {
            w[i * 10 + block] = 40;
        }
    }
    WeightMatrix::from_rows(784, 10, 9, w).unwrap()
}

/// Deterministic seed → image mapping shared by the serving run and the
/// fault-free reference run.
fn image_for_seed(seed: u32) -> Image {
    DigitGen::new(7).sample((seed % 10) as u8, seed % 37)
}

fn blank_image() -> Image {
    Image { label: 0, pixels: vec![0u8; IMG_PIXELS] }
}

/// First `n` request seeds (from 1 upward) the plan classifies as `kind` —
/// victim enumeration is a pure function of the plan, so the suite knows
/// every request's fate before submitting anything.
fn seeds_of_kind(plan: &FaultPlan, kind: FaultKind, n: usize) -> Vec<u32> {
    (1u32..).filter(|&s| plan.classify(s) == kind).take(n).collect()
}

/// Deterministic shuffle: order by a hash of the seed, so victims scatter
/// across the submission stream identically on every run.
fn shuffled(mut seeds: Vec<u32>) -> Vec<u32> {
    seeds.sort_by_key(|&s| splitmix32(s ^ 0x5EED_CAFE));
    seeds
}

/// Fault-free ground truth per seed, computed on a private engine.
fn reference_outputs(backend: &RtlBackend, seeds: &[u32]) -> HashMap<u32, BackendOutput> {
    seeds
        .iter()
        .map(|&s| {
            let img = image_for_seed(s);
            let out = backend.classify_batch(&[&img], &[s], EarlyExit::Off).unwrap();
            (s, out.into_iter().next().unwrap())
        })
        .collect()
}

fn assert_bit_exact(resp: &Response, want: &BackendOutput, seed: u32) {
    assert_eq!(resp.seed, seed, "seed echo mismatch");
    assert_eq!(resp.class, want.class, "class diverged for seed {seed}");
    assert_eq!(resp.spike_counts, want.spike_counts, "counts not bit-exact for seed {seed}");
    assert_eq!(resp.steps_run, want.steps_run, "steps diverged for seed {seed}");
}

/// Mixed chaos over singleton batches: with `max_batch = 1` every request
/// is its own batch, so each request's outcome is exactly determined by
/// its own fault kind — panic victims fail typed, every transient victim
/// recovers bit-exactly via the retry, and every counter is exact.
#[test]
fn mixed_chaos_every_request_resolves_bit_exactly() {
    with_watchdog(Duration::from_secs(120), || {
        let plan = FaultPlan {
            seed: 0x0051_CE55,
            panic_per_mille: 25,
            error_per_mille: 25,
            wrong_len_per_mille: 25,
            latency_per_mille: 25,
            latency_spike: Duration::from_millis(1),
        };
        let panics = seeds_of_kind(&plan, FaultKind::Panic, 8);
        let errors = seeds_of_kind(&plan, FaultKind::TransientError, 10);
        let wrongs = seeds_of_kind(&plan, FaultKind::WrongLength, 6);
        let lates = seeds_of_kind(&plan, FaultKind::LatencySpike, 4);
        let clean = seeds_of_kind(&plan, FaultKind::None, 72);
        let mut all = Vec::new();
        for list in [&panics, &errors, &wrongs, &lates, &clean] {
            all.extend_from_slice(list);
        }
        let all = shuffled(all);

        let cfg = SnnConfig::paper().with_timesteps(4);
        let reference = RtlBackend::new(cfg.clone(), test_weights()).unwrap();
        let expected = reference_outputs(&reference, &all);

        let inner: Arc<dyn Backend> = Arc::new(RtlBackend::new(cfg, test_weights()).unwrap());
        let wrapper = Arc::new(FaultInjectingBackend::new(inner, plan));
        let coord = Coordinator::start(
            Arc::clone(&wrapper) as Arc<dyn Backend>,
            CoordinatorConfig {
                workers: 2,
                queue_depth: 256,
                batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(50) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::off(),
                supervision: SupervisionPolicy {
                    max_restarts_per_worker: 32,
                    backoff_base: Duration::from_micros(50),
                    backoff_cap: Duration::from_millis(1),
                },
            },
        );
        let handle = coord.handle();
        let receivers: Vec<_> = all
            .iter()
            .map(|&s| {
                let rx = loop {
                    match handle.submit(Request::new(image_for_seed(s)).with_seed(s)) {
                        Ok(rx) => break rx,
                        Err(Error::Overloaded(_)) => {
                            std::thread::sleep(Duration::from_micros(100));
                        }
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                };
                (s, rx)
            })
            .collect();
        for (s, rx) in receivers {
            let reply = rx.recv().expect("every request must get a terminal reply");
            assert!(rx.try_recv().is_err(), "duplicate reply for seed {s}");
            if plan.classify(s) == FaultKind::Panic {
                assert!(
                    matches!(reply, Err(Error::BackendPanicked(_))),
                    "hard victim {s} must fail typed, got {reply:?}"
                );
            } else {
                let resp = reply.unwrap_or_else(|e| panic!("seed {s} must recover: {e}"));
                assert_bit_exact(&resp, &expected[&s], s);
            }
        }

        // The restart counter trails the last panicked reply by one
        // supervisor poll; wait for it before asserting exact counts.
        let deadline = Instant::now() + Duration::from_secs(20);
        while coord.metrics().snapshot().worker_restarts < 8 {
            assert!(Instant::now() < deadline, "supervisor never caught all 8 deaths");
            std::thread::sleep(Duration::from_millis(1));
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed, 92);
        assert_eq!(snap.failed, 8, "only the 8 hard panic victims fail");
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.submitted, snap.completed + snap.failed + snap.shed);
        assert_eq!(snap.panics_recovered, 16, "initial attempt + retry per panic victim");
        assert_eq!(snap.worker_restarts, 8, "one death per panicked singleton batch");
        assert_eq!(snap.subbatch_retries, 24, "every faulted singleton retried once");
        assert_eq!(snap.quarantined_engines, 0, "wrapper faults fire before engine checkout");
        let inj = wrapper.injections();
        assert_eq!(inj.panics, 16);
        assert_eq!(inj.errors, 10);
        assert_eq!(inj.wrong_lengths, 6);
        assert_eq!(inj.latency_spikes, 4);
        coord.shutdown();
    });
}

/// Mixed chaos with real batching and fan-out: outcomes of chunk-mates are
/// coupled (a hard victim poisons its twice-failed chunk), so the suite
/// asserts the conservation laws instead of per-request fates — exactly
/// one reply each, every `Ok` bit-exact, metrics conserve, and the pool
/// serves a clean recovery round afterwards.
#[test]
fn batched_chaos_conserves_replies_and_recovers() {
    with_watchdog(Duration::from_secs(120), || {
        let plan = FaultPlan::mixed(0xB47C, 80);
        let panics = seeds_of_kind(&plan, FaultKind::Panic, 5);
        let errors = seeds_of_kind(&plan, FaultKind::TransientError, 8);
        let wrongs = seeds_of_kind(&plan, FaultKind::WrongLength, 5);
        let mut clean = seeds_of_kind(&plan, FaultKind::None, 166);
        let recovery = clean.split_off(150);
        let mut all = Vec::new();
        for list in [&panics, &errors, &wrongs, &clean] {
            all.extend_from_slice(list);
        }
        let all = shuffled(all);
        let total = all.len() as u64;

        let cfg = SnnConfig::paper().with_timesteps(4);
        let reference = RtlBackend::new(cfg.clone(), test_weights()).unwrap();
        let mut everything = all.clone();
        everything.extend_from_slice(&recovery);
        let expected = Arc::new(reference_outputs(&reference, &everything));

        let inner: Arc<dyn Backend> = Arc::new(RtlBackend::new(cfg, test_weights()).unwrap());
        let wrapper = Arc::new(FaultInjectingBackend::new(inner, plan));
        let coord = Coordinator::start(
            Arc::clone(&wrapper) as Arc<dyn Backend>,
            CoordinatorConfig {
                workers: 4,
                queue_depth: 512,
                batch: BatchPolicy { max_batch: 8, max_delay: Duration::from_micros(300) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy { min_batch: 8, max_parts: 2 },
                supervision: SupervisionPolicy::default(),
            },
        );

        let halves: Vec<Vec<u32>> =
            all.chunks(all.len().div_ceil(2)).map(<[u32]>::to_vec).collect();
        let producers: Vec<_> = halves
            .into_iter()
            .map(|half| {
                let handle = coord.handle();
                let expected = Arc::clone(&expected);
                std::thread::spawn(move || {
                    let mut replies = Vec::new();
                    for &s in &half {
                        let rx = loop {
                            match handle.submit(Request::new(image_for_seed(s)).with_seed(s)) {
                                Ok(rx) => break rx,
                                Err(Error::Overloaded(_)) => {
                                    std::thread::sleep(Duration::from_micros(100));
                                }
                                Err(e) => panic!("unexpected submit error: {e}"),
                            }
                        };
                        replies.push((s, rx));
                    }
                    let (mut ok, mut collateral) = (0u64, 0u64);
                    for (s, rx) in replies {
                        let reply = rx.recv().expect("request lost its reply");
                        assert!(rx.try_recv().is_err(), "duplicate reply for seed {s}");
                        match (plan.classify(s), reply) {
                            (FaultKind::Panic, Ok(_)) => panic!("hard victim {s} succeeded"),
                            (FaultKind::Panic, Err(_)) => collateral += 1,
                            (_, Ok(resp)) => {
                                assert_bit_exact(&resp, &expected[&s], s);
                                ok += 1;
                            }
                            // A chunk-mate of a twice-failed chunk: the
                            // error reply is legitimate; what matters is
                            // that it arrived, typed, exactly once.
                            (_, Err(_)) => collateral += 1,
                        }
                    }
                    (ok, collateral)
                })
            })
            .collect();
        let (mut ok_total, mut collateral_total) = (0u64, 0u64);
        for p in producers {
            let (ok, collateral) = p.join().expect("producer panicked");
            ok_total += ok;
            collateral_total += collateral;
        }

        let deadline = Instant::now() + Duration::from_secs(20);
        while coord.metrics().snapshot().worker_restarts == 0 {
            assert!(Instant::now() < deadline, "no worker was ever restarted");
            std::thread::sleep(Duration::from_millis(1));
        }
        let storm = coord.metrics().snapshot();
        assert_eq!(storm.submitted, total);
        assert_eq!(storm.completed, ok_total);
        assert_eq!(storm.failed, collateral_total);
        assert_eq!(storm.shed, 0);
        assert_eq!(
            storm.completed + storm.failed,
            storm.submitted,
            "reply conservation violated under batched chaos"
        );
        let inj = wrapper.injections();
        assert!(inj.panics >= 2, "hard victims never reached a worker");
        assert!(
            storm.worker_restarts * 2 <= inj.panics,
            "each death needs >= 2 injected panics (attempt + retry): {} deaths, {} panics",
            storm.worker_restarts,
            inj.panics
        );

        // Recovery round: the respawned workers and healed engines serve
        // clean requests bit-exactly after the storm.
        let handle = coord.handle();
        for &s in &recovery {
            let resp = handle
                .submit(Request::new(image_for_seed(s)).with_seed(s))
                .unwrap()
                .recv()
                .unwrap()
                .expect("post-chaos request failed");
            assert_bit_exact(&resp, &expected[&s], s);
        }
        let after = coord.metrics().snapshot();
        assert_eq!(
            after.completed,
            ok_total + recovery.len() as u64,
            "the pool must keep serving after the storm"
        );
        coord.shutdown();
    });
}

/// Seed-echo stub backend (instant), the substrate for latency and
/// shutdown chaos where real compute would only add noise.
struct EchoStub {
    cfg: SnnConfig,
}

impl Backend for EchoStub {
    fn name(&self) -> &'static str {
        "echo-stub"
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        _early: EarlyExit,
    ) -> snn_rtl::Result<Vec<BackendOutput>> {
        Ok(images
            .iter()
            .zip(seeds)
            .map(|(_, &s)| BackendOutput {
                class: (s % 10) as u8,
                spike_counts: vec![s],
                steps_run: 1,
            })
            .collect())
    }

    fn config(&self) -> &SnnConfig {
        &self.cfg
    }
}

/// An injected latency spike stalls the single worker long enough that
/// every deadline-carrying request queued behind it expires — all of them
/// must be shed with typed replies at pop time, not computed late.
#[test]
fn latency_spikes_shed_expired_deadlines() {
    with_watchdog(Duration::from_secs(60), || {
        let plan = FaultPlan {
            seed: 0xD1A7,
            panic_per_mille: 0,
            error_per_mille: 0,
            wrong_len_per_mille: 0,
            latency_per_mille: 200,
            latency_spike: Duration::from_millis(40),
        };
        let victim = seeds_of_kind(&plan, FaultKind::LatencySpike, 1)[0];
        let clean = seeds_of_kind(&plan, FaultKind::None, 6);
        let stub: Arc<dyn Backend> = Arc::new(EchoStub { cfg: SnnConfig::paper() });
        let wrapper = Arc::new(FaultInjectingBackend::new(stub, plan));
        let coord = Coordinator::start(
            Arc::clone(&wrapper) as Arc<dyn Backend>,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 32,
                batch: BatchPolicy { max_batch: 8, max_delay: Duration::from_micros(200) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::off(),
                supervision: SupervisionPolicy::default(),
            },
        );
        let handle = coord.handle();

        // The spike victim occupies the only worker for 40 ms. Wait for
        // its batch to actually be in flight (the batch counter bumps just
        // before the backend call) so the doomed requests cannot ride in
        // the victim's own batch.
        let slow_rx = handle.submit(Request::new(blank_image()).with_seed(victim)).unwrap();
        let t0 = Instant::now();
        while coord.metrics().snapshot().batches == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "victim batch never dispatched");
            std::thread::sleep(Duration::from_micros(200));
        }
        // ...so these 1 ms deadlines are long dead by the next pop.
        let doomed: Vec<_> = clean
            .iter()
            .map(|&s| {
                let req = Request::new(blank_image())
                    .with_seed(s)
                    .with_deadline(Instant::now() + Duration::from_millis(1));
                handle.submit(req).unwrap()
            })
            .collect();

        let slow = slow_rx.recv().unwrap().expect("the spiked batch still succeeds");
        assert_eq!(slow.spike_counts, vec![victim]);
        for rx in doomed {
            let reply = rx.recv().expect("shed request lost its reply");
            assert!(matches!(reply, Err(Error::Shed(_))), "want Shed, got {reply:?}");
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.shed, 6);
        assert_eq!(snap.deadline_expired, 6);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        assert_eq!(snap.submitted, 7);
        assert_eq!(wrapper.injections().latency_spikes, 1);
        coord.shutdown();
    });
}

/// Seed-echo stub that records the submission time of every inner call —
/// the probe for asserting *when* each sub-batch reached the backend.
struct RecordingStub {
    cfg: SnnConfig,
    calls: std::sync::Mutex<Vec<(Vec<u32>, Instant)>>,
}

impl Backend for RecordingStub {
    fn name(&self) -> &'static str {
        "recording-stub"
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        _early: EarlyExit,
    ) -> snn_rtl::Result<Vec<BackendOutput>> {
        // pallas-lint: lock(chaos.recording_calls)
        lock_recover(&self.calls).push((seeds.to_vec(), Instant::now()));
        // pallas-lint: end-lock(chaos.recording_calls)
        Ok(images
            .iter()
            .zip(seeds)
            .map(|(_, &s)| BackendOutput {
                class: (s % 10) as u8,
                spike_counts: vec![s],
                steps_run: 1,
            })
            .collect())
    }

    fn config(&self) -> &SnnConfig {
        &self.cfg
    }
}

/// Bugfix regression: a latency-spike victim must stall only its own
/// sub-batch. The fault-free siblings' inner call lands *before* the
/// injected sleep, the victims' call lands after it, the merged reply
/// keeps submission order bit-exactly, and a victim-free batch pays no
/// delay at all.
#[test]
fn latency_spike_delays_only_the_victims_subbatch() {
    with_watchdog(Duration::from_secs(60), || {
        let spike = Duration::from_millis(80);
        let plan = FaultPlan {
            seed: 0xD1A7,
            panic_per_mille: 0,
            error_per_mille: 0,
            wrong_len_per_mille: 0,
            latency_per_mille: 200,
            latency_spike: spike,
        };
        let victims = seeds_of_kind(&plan, FaultKind::LatencySpike, 2);
        let clean = seeds_of_kind(&plan, FaultKind::None, 4);
        let stub = Arc::new(RecordingStub {
            cfg: SnnConfig::paper(),
            calls: std::sync::Mutex::new(Vec::new()),
        });
        let wrapper =
            FaultInjectingBackend::new(Arc::clone(&stub) as Arc<dyn Backend>, plan);

        // Interleave victims among clean seeds so the splice has to work
        // for non-contiguous victim positions.
        let seeds =
            vec![clean[0], victims[0], clean[1], clean[2], victims[1], clean[3]];
        let imgs: Vec<Image> = seeds.iter().map(|_| blank_image()).collect();
        let refs: Vec<&Image> = imgs.iter().collect();
        let t0 = Instant::now();
        let out = wrapper.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap();

        // Merged reply: submission order, one output per request, echo
        // bit-exact — the split is invisible in the results.
        assert_eq!(out.len(), seeds.len());
        for (i, &s) in seeds.iter().enumerate() {
            assert_eq!(out[i].spike_counts, vec![s], "slot {i} lost its order");
            assert_eq!(out[i].class, (s % 10) as u8);
        }

        // The siblings' inner call must predate the sleep; the victims'
        // must trail it. (Half-spike tolerance: the only work before the
        // first call is vector bookkeeping.)
        // pallas-lint: lock(chaos.recording_calls)
        let calls = lock_recover(&stub.calls).clone();
        // pallas-lint: end-lock(chaos.recording_calls)
        assert_eq!(calls.len(), 2, "exactly one sibling call + one victim call");
        let (rest_seeds, rest_t) = &calls[0];
        let (vic_seeds, vic_t) = &calls[1];
        assert_eq!(rest_seeds, &vec![clean[0], clean[1], clean[2], clean[3]]);
        assert_eq!(vic_seeds, &victims);
        assert!(
            rest_t.duration_since(t0) < spike / 2,
            "fault-free siblings waited {:?} behind the injected spike",
            rest_t.duration_since(t0)
        );
        assert!(
            vic_t.duration_since(t0) >= spike,
            "victims' sub-batch ran {:?} after submit — before the spike elapsed",
            vic_t.duration_since(t0)
        );
        assert_eq!(wrapper.injections().latency_spikes, 1);

        // A victim-free batch takes the single-call path: no split, no
        // sleep.
        let t1 = Instant::now();
        let out = wrapper
            .classify_batch(&refs[..4], &clean, EarlyExit::Off)
            .unwrap();
        assert!(t1.elapsed() < spike / 2, "victim-free batch was delayed");
        assert_eq!(out.len(), 4);
        // pallas-lint: lock(chaos.recording_calls)
        assert_eq!(lock_recover(&stub.calls).len(), 3);
        // pallas-lint: end-lock(chaos.recording_calls)
        assert_eq!(wrapper.injections().latency_spikes, 1, "no spike may fire");
    });
}

/// Panic storm past the restart budget: once every worker slot is out of
/// restarts, the coordinator must reject the stranded backlog with typed
/// `ShuttingDown` replies — every accepted request still resolves, the
/// restart counter lands exactly on `workers x budget`, and nothing hangs.
#[test]
fn worker_budget_exhaustion_drains_or_rejects_everything() {
    with_watchdog(Duration::from_secs(60), || {
        let plan = FaultPlan {
            seed: 0xBEEF,
            panic_per_mille: 120,
            error_per_mille: 0,
            wrong_len_per_mille: 0,
            latency_per_mille: 0,
            latency_spike: Duration::ZERO,
        };
        let victims = (1..=400u32).filter(|&s| plan.classify(s) == FaultKind::Panic).count();
        assert!(victims >= 12, "plan seed produced too few hard victims: {victims}");

        let stub: Arc<dyn Backend> = Arc::new(EchoStub { cfg: SnnConfig::paper() });
        let wrapper = Arc::new(FaultInjectingBackend::new(stub, plan));
        let coord = Coordinator::start(
            Arc::clone(&wrapper) as Arc<dyn Backend>,
            CoordinatorConfig {
                workers: 2,
                queue_depth: 64,
                batch: BatchPolicy { max_batch: 4, max_delay: Duration::from_micros(100) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::off(),
                supervision: SupervisionPolicy {
                    max_restarts_per_worker: 2,
                    backoff_base: Duration::from_micros(50),
                    backoff_cap: Duration::from_micros(500),
                },
            },
        );
        let handle = coord.handle();

        let mut accepted = Vec::new();
        let mut shut_out = 0u64;
        for s in 1..=400u32 {
            loop {
                match handle.submit(Request::new(blank_image()).with_seed(s)) {
                    Ok(rx) => {
                        accepted.push((s, rx));
                        break;
                    }
                    Err(Error::Overloaded(_)) => {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    Err(Error::ShuttingDown(_)) => {
                        shut_out += 1;
                        break;
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
            }
        }

        let (mut ok, mut panicked, mut swept) = (0u64, 0u64, 0u64);
        for (s, rx) in accepted {
            match rx.recv().expect("accepted request lost its reply") {
                Ok(resp) => {
                    assert_ne!(plan.classify(s), FaultKind::Panic, "hard victim {s} succeeded");
                    assert_eq!(resp.spike_counts, vec![s], "cross-wired echo for seed {s}");
                    ok += 1;
                }
                Err(Error::BackendPanicked(_)) => panicked += 1,
                Err(Error::ShuttingDown(_)) => swept += 1,
                Err(e) => panic!("untyped terminal reply for seed {s}: {e}"),
            }
        }

        assert!(
            swept > 0 || shut_out > 0,
            "the dead pool must reject its backlog (swept {swept}, shut out {shut_out})"
        );
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.worker_restarts, 4, "2 workers x restart budget 2");
        assert_eq!(snap.completed, ok);
        assert_eq!(snap.failed, panicked + swept);
        assert_eq!(snap.shed, 0);
        assert_eq!(
            snap.submitted,
            ok + panicked + swept,
            "every accepted request must resolve exactly once"
        );
        assert_eq!(
            wrapper.injections().panics,
            12,
            "6 worker lives, each consumed by one panicked batch (attempt + retry)"
        );
        coord.shutdown();
    });
}

/// Panics on the victim while holding an engine checked out of its own
/// pool — the quarantine path the fault wrapper (which panics before any
/// engine checkout) cannot reach.
struct EngineHoldingPanicBackend {
    cfg: SnnConfig,
    engines: InstancePool<Vec<u64>>,
    victim: u32,
}

impl Backend for EngineHoldingPanicBackend {
    fn name(&self) -> &'static str {
        "engine-holding-panic-stub"
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        _early: EarlyExit,
    ) -> snn_rtl::Result<Vec<BackendOutput>> {
        let mut engine = self.engines.checkout();
        engine.push(seeds.len() as u64);
        if seeds.contains(&self.victim) {
            panic!("panic with engine state {:?} checked out", engine.len());
        }
        Ok(images
            .iter()
            .zip(seeds)
            .map(|(_, &s)| BackendOutput {
                class: (s % 10) as u8,
                spike_counts: vec![s],
                steps_run: 1,
            })
            .collect())
    }

    fn config(&self) -> &SnnConfig {
        &self.cfg
    }

    fn quarantined_engines(&self) -> u64 {
        self.engines.quarantined()
    }
}

/// A panic that unwinds through a live engine checkout must poison the
/// slot; the next checkout heals it by quarantining the torn engine and
/// rebuilding from the factory — capacity intact, gauge mirrored.
#[test]
fn panicking_engine_is_quarantined_not_reused() {
    with_watchdog(Duration::from_secs(60), || {
        let backend = Arc::new(EngineHoldingPanicBackend {
            cfg: SnnConfig::paper(),
            engines: InstancePool::new(1, Vec::new),
            victim: 0xE5E5,
        });
        let coord = Coordinator::start(
            Arc::clone(&backend) as Arc<dyn Backend>,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 8,
                batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(10) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::off(),
                supervision: SupervisionPolicy {
                    max_restarts_per_worker: 4,
                    backoff_base: Duration::from_micros(50),
                    backoff_cap: Duration::from_millis(1),
                },
            },
        );
        let handle = coord.handle();
        let bad = handle
            .submit(Request::new(blank_image()).with_seed(0xE5E5))
            .unwrap()
            .recv()
            .expect("panicked batch must still send a terminal reply");
        assert!(matches!(bad, Err(Error::BackendPanicked(_))), "got {bad:?}");
        let good = handle
            .submit(Request::new(blank_image()).with_seed(9))
            .unwrap()
            .recv()
            .unwrap()
            .expect("server must survive the engine panic");
        assert_eq!(good.class, 9);
        // Initial attempt and retry both panicked mid-checkout: both torn
        // engines were quarantined (at the heal on the next checkout), and
        // the single-slot pool still serves — capacity never shrank.
        assert_eq!(backend.engines.quarantined(), 2, "attempt + retry engines quarantined");
        assert_eq!(backend.engines.capacity(), 1);
        let deadline = Instant::now() + Duration::from_secs(10);
        while coord.metrics().snapshot().worker_restarts == 0 {
            assert!(Instant::now() < deadline, "supervisor never restarted the worker");
            std::thread::sleep(Duration::from_millis(1));
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.panics_recovered, 2);
        assert_eq!(snap.worker_restarts, 1);
        assert_eq!(snap.quarantined_engines, 2, "gauge must mirror the backend's pool");
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 1);
        coord.shutdown();
    });
}
