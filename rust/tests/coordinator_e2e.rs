//! End-to-end coordinator tests over the real compiled artifacts: the
//! full request path (submit → batch → PJRT execute → respond), early-exit
//! scheduling, and failure injection.

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::artifacts_dir;
use snn_rtl::coordinator::{
    Backend, BackendOutput, BatchPolicy, BehavioralBackend, Coordinator, CoordinatorConfig,
    FanoutPolicy, Request, SupervisionPolicy, XlaBackend,
};
use snn_rtl::data::{codec, DigitGen, Image};
use snn_rtl::error::Error;
use snn_rtl::runtime::XlaSnn;
use snn_rtl::snn::EarlyExit;
use snn_rtl::SnnConfig;

/// Load the PJRT stack, or skip (stub builds without the `xla` feature
/// error out of `load` even when artifacts exist).
fn load_xla(dir: &std::path::Path) -> Option<XlaSnn> {
    match XlaSnn::load(dir) {
        Ok(snn) => Some(snn),
        Err(e) => {
            eprintln!("skipped: XLA runtime unavailable ({e})");
            None
        }
    }
}

#[test]
fn xla_backed_coordinator_serves_accurately() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(snn) = load_xla(&dir) else { return };
    let backend = Arc::new(XlaBackend::new(snn));
    let coord = Coordinator::start(
        backend,
        CoordinatorConfig {
            workers: 2,
            queue_depth: 512,
            batch: BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(2) },
            early: EarlyExit::Off,
            fanout: FanoutPolicy::default(),
            supervision: SupervisionPolicy::default(),
        },
    );
    let handle = coord.handle();
    let gen = DigitGen::new(2);
    let n = 80usize;
    let receivers: Vec<_> = (0..n)
        .map(|i| {
            let class = (i % 10) as u8;
            let img = gen.sample(class, (i / 10) as u32);
            (class, handle.submit(Request::new(img).with_seed(500 + i as u32)).unwrap())
        })
        .collect();
    let mut hits = 0usize;
    for (class, rx) in receivers {
        let resp = rx.recv().unwrap().unwrap();
        if resp.class == class {
            hits += 1;
        }
    }
    let acc = hits as f64 / n as f64;
    assert!(acc > 0.9, "serving accuracy {acc}");
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.completed as usize, n);
    assert_eq!(snap.failed, 0);
    assert!(snap.mean_batch_size > 1.0, "batcher never batched");
    coord.shutdown();
}

#[test]
fn early_exit_saves_timesteps_on_xla() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(snn) = load_xla(&dir) else { return };
    let window = snn.config().timesteps;
    let chunk = snn.chunk_steps();
    let backend = Arc::new(XlaBackend::new(snn));
    let coord = Coordinator::start(
        backend,
        CoordinatorConfig {
            workers: 1,
            queue_depth: 64,
            batch: BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(1) },
            early: EarlyExit::Margin { margin: 2, min_steps: chunk },
            fanout: FanoutPolicy::default(),
            supervision: SupervisionPolicy::default(),
        },
    );
    let handle = coord.handle();
    let gen = DigitGen::new(2);
    let mut total_steps = 0u64;
    let n = 24usize;
    let mut hits = 0usize;
    for i in 0..n {
        let class = (i % 10) as u8;
        let resp = handle.classify(gen.sample(class, 50 + (i / 10) as u32)).unwrap();
        total_steps += u64::from(resp.steps_run);
        if resp.class == class {
            hits += 1;
        }
    }
    let mean_steps = total_steps as f64 / n as f64;
    assert!(
        mean_steps < f64::from(window),
        "early exit never saved a chunk: mean {mean_steps} vs window {window}"
    );
    assert!(hits as f64 / n as f64 > 0.85, "early-exit accuracy dropped: {hits}/{n}");
    coord.shutdown();
}

#[test]
fn xla_and_behavioral_coordinators_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let w = codec::load_weights(dir.join("weights.bin")).unwrap();
    let cfg = w.config();
    let Some(snn) = load_xla(&dir) else { return };
    let xla = Arc::new(XlaBackend::new(snn));
    let beh = Arc::new(BehavioralBackend::new(cfg, w.weights).unwrap());

    let mk = |backend: Arc<dyn Backend>| {
        Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 64,
                batch: BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(1) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::default(),
                supervision: SupervisionPolicy::default(),
            },
        )
    };
    let cx = mk(xla);
    let cb = mk(beh);
    let gen = DigitGen::new(2);
    for i in 0..20u32 {
        let img = gen.sample((i % 10) as u8, i / 10);
        let rx = cx.handle().submit(Request::new(img.clone()).with_seed(900 + i)).unwrap();
        let rb = cb.handle().submit(Request::new(img).with_seed(900 + i)).unwrap();
        let a = rx.recv().unwrap().unwrap();
        let b = rb.recv().unwrap().unwrap();
        assert_eq!(a.class, b.class, "request {i}");
        assert_eq!(a.spike_counts, b.spike_counts, "request {i}");
    }
    cx.shutdown();
    cb.shutdown();
}

// ---------------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------------

/// A backend that fails every batch containing a poisoned seed.
struct FaultyBackend {
    cfg: SnnConfig,
}

impl Backend for FaultyBackend {
    fn name(&self) -> &'static str {
        "faulty"
    }
    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        _early: EarlyExit,
    ) -> snn_rtl::Result<Vec<BackendOutput>> {
        if seeds.contains(&0xBAD) {
            return Err(Error::Xla("injected backend fault".into()));
        }
        Ok(images
            .iter()
            .map(|_| BackendOutput { class: 0, spike_counts: vec![0; 10], steps_run: 1 })
            .collect())
    }
    fn config(&self) -> &SnnConfig {
        &self.cfg
    }
}

#[test]
fn backend_fault_fails_batch_not_server() {
    let backend = Arc::new(FaultyBackend { cfg: SnnConfig::paper() });
    let coord = Coordinator::start(
        backend,
        CoordinatorConfig {
            workers: 1,
            queue_depth: 16,
            // Batch of 1 so the poisoned request fails alone.
            batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(10) },
            early: EarlyExit::Off,
            fanout: FanoutPolicy::default(),
            supervision: SupervisionPolicy::default(),
        },
    );
    let handle = coord.handle();
    let img = Image { label: 0, pixels: vec![0; 784] };

    // Poisoned request errors (the fault is persistent, so the retry
    // fails too)...
    let bad =
        handle.submit(Request::new(img.clone()).with_seed(0xBAD)).unwrap().recv().unwrap();
    assert!(bad.is_err(), "poisoned request must surface the backend error");

    // ...and the server keeps serving afterwards.
    let good = handle.submit(Request::new(img).with_seed(1)).unwrap().recv().unwrap();
    assert!(good.is_ok(), "server must survive a failed batch");
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.failed, 1);
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.subbatch_retries, 1, "the failed singleton batch is retried once");
    coord.shutdown();
}
