//! Adversarial concurrency suite for the sharded coordinator: many
//! producers against the work-stealing ingress, intra-batch fan-out
//! reassembly, a pinned-worker steal-path scenario, and shutdown racing
//! live submissions. Every test runs under a watchdog so a regression
//! shows up as a failure, never as a hung CI job.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use snn_rtl::coordinator::{
    Backend, BackendOutput, BatchPolicy, Coordinator, CoordinatorConfig, FanoutPolicy,
    Request, SupervisionPolicy,
};
use snn_rtl::data::{Image, IMG_PIXELS};
use snn_rtl::error::Error;
use snn_rtl::snn::EarlyExit;
use snn_rtl::SnnConfig;

/// Run `body` on a helper thread and fail loudly if it does not finish
/// within `limit` — the concurrency suite's hang detector. The panic
/// unwinds in the main test thread, so cargo reports a normal failure.
fn with_watchdog<F: FnOnce() + Send + 'static>(limit: Duration, body: F) {
    let (done_tx, done_rx) = mpsc::channel();
    let runner = std::thread::spawn(move || {
        body();
        let _ = done_tx.send(());
    });
    match done_rx.recv_timeout(limit) {
        // Finished or panicked: join and propagate the real outcome.
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(payload) = runner.join() {
                std::panic::resume_unwind(payload);
            }
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("watchdog: test exceeded {limit:?} — likely a hang/deadlock")
        }
    }
}

fn image_for(seed: u32) -> Image {
    Image { label: (seed % 10) as u8, pixels: vec![(seed % 251) as u8; IMG_PIXELS] }
}

/// Deterministic backend that echoes each request's seed back through the
/// response (`class = seed % 10`, `spike_counts[0] = seed`,
/// `spike_counts[1] = checksum(image)`), so any cross-wiring of requests
/// and replies — lost, duplicated, or reordered sub-batch reassembly —
/// is directly observable at the client. `steps_run` reports the
/// (sub-)batch length the request was executed in.
struct EchoBackend {
    cfg: SnnConfig,
    slow_seed: Option<u32>,
    slow_for: Duration,
}

impl EchoBackend {
    fn new() -> Self {
        EchoBackend { cfg: SnnConfig::paper(), slow_seed: None, slow_for: Duration::ZERO }
    }

    fn with_slow_seed(seed: u32, slow_for: Duration) -> Self {
        EchoBackend { slow_seed: Some(seed), slow_for, ..EchoBackend::new() }
    }
}

fn checksum(img: &Image) -> u32 {
    img.pixels.iter().fold(0u32, |h, &b| h.wrapping_mul(31).wrapping_add(u32::from(b)))
}

impl Backend for EchoBackend {
    fn name(&self) -> &'static str {
        "echo"
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        _early: EarlyExit,
    ) -> snn_rtl::Result<Vec<BackendOutput>> {
        if let Some(slow) = self.slow_seed {
            if seeds.contains(&slow) {
                std::thread::sleep(self.slow_for);
            }
        }
        Ok(images
            .iter()
            .zip(seeds)
            .map(|(img, &seed)| BackendOutput {
                class: (seed % 10) as u8,
                spike_counts: vec![seed, checksum(img)],
                steps_run: images.len() as u32,
            })
            .collect())
    }

    fn config(&self) -> &SnnConfig {
        &self.cfg
    }
}

/// The headline stress test: 6 producers x 250 requests with mixed batch
/// sizes (the batcher forms anything from singletons to 24-item batches,
/// and fan-out splits the large ones), asserting zero lost, duplicated,
/// or cross-wired replies and in-order sub-batch reassembly.
#[test]
fn stress_many_producers_no_loss_no_duplication() {
    with_watchdog(Duration::from_secs(120), || {
        const PRODUCERS: u32 = 6;
        const PER_PRODUCER: u32 = 250;
        let backend = Arc::new(EchoBackend::new());
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 4,
                queue_depth: 512,
                batch: BatchPolicy { max_batch: 24, max_delay: Duration::from_micros(300) },
                early: EarlyExit::Off,
                // Low crossover so the stress load exercises fan-out
                // reassembly constantly, not just on rare giant batches.
                fanout: FanoutPolicy { min_batch: 8, max_parts: 3 },
                supervision: SupervisionPolicy::default(),
            },
        );

        let producers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let handle = coord.handle();
                std::thread::spawn(move || {
                    let mut replies = Vec::new();
                    for i in 0..PER_PRODUCER {
                        let seed = p * 10_000 + i;
                        let img = image_for(seed);
                        let expect_sum = checksum(&img);
                        // Mixed arrival pattern: bursts then a breather, so
                        // batch sizes vary across the whole range.
                        if i % 17 == 0 {
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        let rx = loop {
                            match handle.submit(Request::new(image_for(seed)).with_seed(seed)) {
                                Ok(rx) => break rx,
                                Err(Error::Overloaded(_)) => {
                                    std::thread::sleep(Duration::from_micros(50));
                                }
                                Err(e) => panic!("unexpected submit error: {e}"),
                            }
                        };
                        replies.push((seed, expect_sum, rx));
                    }
                    for (seed, expect_sum, rx) in replies {
                        let resp = rx.recv().expect("reply channel dropped").expect("backend ok");
                        assert_eq!(resp.seed, seed, "seed echo mismatch");
                        assert_eq!(resp.class, (seed % 10) as u8, "cross-wired class");
                        assert_eq!(
                            resp.spike_counts[0], seed,
                            "reply carries another request's payload"
                        );
                        assert_eq!(
                            resp.spike_counts[1], expect_sum,
                            "reply image checksum mismatch (reassembly disorder)"
                        );
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().expect("producer panicked");
        }

        let snap = coord.metrics().snapshot();
        let total = u64::from(PRODUCERS * PER_PRODUCER);
        assert_eq!(snap.completed, total, "every accepted request answered exactly once");
        assert_eq!(snap.failed, 0);
        assert!(
            snap.fanout_batches > 0,
            "stress run must exercise the fan-out path (mean batch {:.2})",
            snap.mean_batch_size
        );
        coord.shutdown();
    });
}

/// Steal-path pin: one worker gets stuck on a deliberately slow batch;
/// its queued requests must be drained by the sibling long before the
/// slow batch completes, and the steal counter must show it.
#[test]
fn siblings_steal_from_blocked_workers_shard() {
    with_watchdog(Duration::from_secs(60), || {
        const SLOW_SEED: u32 = 0xDEAD;
        let slow_for = Duration::from_millis(800);
        let backend = Arc::new(EchoBackend::with_slow_seed(SLOW_SEED, slow_for));
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 2,
                queue_depth: 256,
                // Singleton batches: the slow request occupies exactly one
                // worker, everything else is independent.
                batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(100) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::off(),
                supervision: SupervisionPolicy::default(),
            },
        );
        let handle = coord.handle();

        let slow_rx =
            handle.submit(Request::new(image_for(SLOW_SEED)).with_seed(SLOW_SEED)).unwrap();
        // Give a worker time to pick the slow request up.
        std::thread::sleep(Duration::from_millis(50));

        // Burst 40 fast requests; shortest-queue placement spreads them
        // over both shards, including the blocked worker's.
        let t0 = Instant::now();
        let fast: Vec<_> = (0..40u32)
            .map(|i| handle.submit(Request::new(image_for(i)).with_seed(i)).unwrap())
            .collect();
        for rx in fast {
            rx.recv().unwrap().unwrap();
        }
        let fast_elapsed = t0.elapsed();
        assert!(
            fast_elapsed < slow_for,
            "fast requests waited on the blocked worker ({fast_elapsed:?} >= {slow_for:?}) — \
             stealing is not draining its shard"
        );
        let stolen = coord.metrics().snapshot().steals;
        assert!(stolen > 0, "sibling must have stolen from the blocked worker's shard");

        slow_rx.recv().unwrap().unwrap();
        coord.shutdown();
    });
}

/// Shutdown under load: submissions racing `Coordinator::stop` must all
/// resolve with a response or a *typed* refusal (`Overloaded` before the
/// close, `ShuttingDown` after — at submit or as a drain-reject reply) —
/// never a dropped channel, never a hang. The watchdog is the assertion.
#[test]
fn shutdown_under_load_resolves_every_submission() {
    with_watchdog(Duration::from_secs(60), || {
        const PRODUCERS: u32 = 4;
        const PER_PRODUCER: u32 = 300;
        let backend = Arc::new(EchoBackend::new());
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 3,
                queue_depth: 64,
                batch: BatchPolicy { max_batch: 8, max_delay: Duration::from_micros(200) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy { min_batch: 8, max_parts: 2 },
                supervision: SupervisionPolicy::default(),
            },
        );

        // Handshake instead of a timed sleep: the main thread stops the
        // coordinator once a fraction of the flood has been submitted, so
        // the remaining (majority of) submissions deterministically race
        // the shutdown on any machine speed.
        let submissions = Arc::new(AtomicU64::new(0));

        let producers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let handle = coord.handle();
                let submissions = Arc::clone(&submissions);
                std::thread::spawn(move || {
                    let mut accepted = 0u64;
                    let mut rejected = 0u64;
                    let mut shut_out = 0u64;
                    let mut resolved = 0u64;
                    for i in 0..PER_PRODUCER {
                        let seed = p * 10_000 + i;
                        submissions.fetch_add(1, Ordering::Relaxed);
                        match handle.submit(Request::new(image_for(seed)).with_seed(seed)) {
                            Ok(rx) => {
                                accepted += 1;
                                // Every accepted request must get exactly one
                                // terminal reply — a response, or the typed
                                // drain-reject. A dropped channel is a lost
                                // request and fails the test.
                                match rx.recv().expect("accepted request lost its reply") {
                                    Ok(resp) => {
                                        assert_eq!(resp.seed, seed);
                                        resolved += 1;
                                    }
                                    Err(Error::ShuttingDown(_)) => resolved += 1,
                                    Err(e) => panic!("untyped terminal reply: {e}"),
                                }
                            }
                            Err(Error::Overloaded(_)) => rejected += 1,
                            Err(Error::ShuttingDown(_)) => {
                                rejected += 1;
                                shut_out += 1;
                            }
                            Err(e) => panic!("unexpected submit error: {e}"),
                        }
                    }
                    (accepted, rejected, shut_out, resolved)
                })
            })
            .collect();

        // Shut down mid-flood: after at most 1/6 of the submissions, at
        // least 1000 more are still to come, so some must hit the closed
        // queue. The watchdog bounds the spin.
        while submissions.load(Ordering::Relaxed) < u64::from(PRODUCERS * PER_PRODUCER) / 6 {
            std::thread::sleep(Duration::from_micros(500));
        }
        coord.stop();

        let mut accepted = 0u64;
        let mut rejected = 0u64;
        let mut shut_out = 0u64;
        let mut resolved = 0u64;
        for p in producers {
            let (a, r, s, d) = p.join().expect("producer panicked");
            accepted += a;
            rejected += r;
            shut_out += s;
            resolved += d;
        }
        assert_eq!(
            accepted + rejected,
            u64::from(PRODUCERS * PER_PRODUCER),
            "every submission must resolve to accept or reject"
        );
        assert_eq!(resolved, accepted, "every accepted submission must resolve");
        assert!(
            shut_out > 0,
            "shutdown raced no submission — the handshake stopped too late"
        );
    });
}

/// Sub-batch fan-out reassembly under a single worker: one large batch
/// splits across engines, and `steps_run` (which the echo backend sets to
/// the executed sub-batch length) proves the split actually happened
/// while the seed echo proves order was restored.
#[test]
fn fanout_splits_large_batches_and_preserves_order() {
    with_watchdog(Duration::from_secs(60), || {
        let backend = Arc::new(EchoBackend::new());
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 256,
                // Generous delay: the batch dispatches the moment it is
                // full, so this only pads against CI scheduler stalls
                // mid-burst — it must not carve the 64 submits into
                // sub-crossover batches.
                batch: BatchPolicy { max_batch: 64, max_delay: Duration::from_millis(500) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy { min_batch: 32, max_parts: 4 },
                supervision: SupervisionPolicy::default(),
            },
        );
        let handle = coord.handle();
        let receivers: Vec<_> = (0..64u32)
            .map(|i| (i, handle.submit(Request::new(image_for(i)).with_seed(i)).unwrap()))
            .collect();
        let mut saw_subbatch = false;
        for (seed, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.seed, seed);
            assert_eq!(resp.spike_counts[0], seed, "reassembly must restore order");
            // A fanned 64-batch runs as sub-batches of at most 16.
            if resp.steps_run <= 16 {
                saw_subbatch = true;
            }
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed, 64);
        // The setup guarantees a fan-out-eligible batch (single worker,
        // 64 queued submits, max_batch 64 >= min_batch 32) — an absent
        // split is a fan-out regression, not an acceptable schedule.
        assert!(snap.fanout_batches >= 1, "large batch never fanned out");
        assert!(
            saw_subbatch,
            "fan-out recorded but every request reports a full-size batch"
        );
        coord.shutdown();
    });
}
