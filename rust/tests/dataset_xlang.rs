//! Cross-language dataset contract: the SNND files written by the Python
//! build path must be *byte-identical* to what the Rust generator produces
//! for the same seeds — the strongest possible check of the integer
//! renderer mirror.

mod common;

use common::artifacts_dir;
use snn_rtl::data::{codec, DigitGen};
use snn_rtl::runtime::Manifest;

#[test]
fn test_set_prefix_regenerates_byte_identically() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let seed = manifest.u32("test_seed").unwrap();
    let ds = codec::load_dataset(dir.join("digits_test.bin")).unwrap();
    let gen = DigitGen::new(seed);
    // Full-prefix check over the first 200 samples (interleaved layout:
    // position i*10+c holds class c sample i).
    for pos in 0..200.min(ds.len()) {
        let class = (pos % 10) as u8;
        let index = (pos / 10) as u32;
        let expected = gen.sample(class, index);
        assert_eq!(ds.images[pos].label, class, "label at {pos}");
        assert_eq!(
            ds.images[pos].pixels, expected.pixels,
            "pixel divergence at position {pos} (class {class}, index {index})"
        );
    }
}

#[test]
fn train_set_spot_checks() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let seed = manifest.u32("train_seed").unwrap();
    let ds = codec::load_dataset(dir.join("digits_train.bin")).unwrap();
    let gen = DigitGen::new(seed);
    for pos in [0usize, 77, 1234, ds.len() - 1] {
        let class = (pos % 10) as u8;
        let index = (pos / 10) as u32;
        assert_eq!(
            ds.images[pos].pixels,
            gen.sample(class, index).pixels,
            "train set diverges at {pos}"
        );
    }
}

#[test]
fn dataset_statistics_sane() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = codec::load_dataset(dir.join("digits_test.bin")).unwrap();
    let hist = ds.class_histogram();
    let per_class = hist[0];
    assert!(hist.iter().all(|&c| c == per_class), "unbalanced: {hist:?}");
    // Ink statistics: every image has a plausible stroke mass.
    for (i, img) in ds.images.iter().enumerate().step_by(97) {
        let ink = img.pixels.iter().filter(|&&p| p > 0).count();
        assert!((40..600).contains(&ink), "image {i} has {ink} inked pixels");
    }
}
