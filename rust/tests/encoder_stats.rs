//! Statistical and seed-stability tests for the Poisson encoder and its
//! PRNG lanes.
//!
//! Two guards ahead of the planned SIMD vectorization of `prng/mod.rs`:
//!
//! * a **chi-squared bound** tying the encoder's measured spike rate to
//!   the architectural `intensity/256` law over a long deterministic run
//!   (a vectorized encoder that subtly permutes lanes or drops draws
//!   shifts these counts immediately), with the exact spike totals pinned
//!   on top of the statistical bound;
//! * a **seed-stability pin** of the first 64 draws of the per-pixel PRNG
//!   lanes (4 lanes × 16 steps) under the `pixel_seed` contract — the
//!   values a SIMD lane shuffle would scramble first.

use snn_rtl::data::{Image, IMG_PIXELS};
use snn_rtl::prng::StreamBank;
use snn_rtl::snn::PoissonEncoder;

/// First 16 post-seed states of PRNG lanes 0..4 for image seed
/// `0xFACE_FEED` (`state0 = pixel_seed(seed, lane)`, then 16 xorshift32
/// steps; the register value *is* the draw). Pinned from the
/// splitmix32/xorshift32 contract shared with the Python layers.
const LANE_SEED: u32 = 0xFACE_FEED;
const LANE_DRAWS: [[u32; 16]; 4] = [
    [
        2847656960, 3612288957, 1152078401, 4069507888, 1473318596, 3074362816,
        2254698211, 4014128444, 2756266126, 641796706, 3869537636, 1762717024,
        3810930942, 2181410338, 3489615234, 4021078533,
    ],
    [
        3364950257, 3144151926, 3828035506, 3128476892, 4269907981, 2592918765,
        1631371717, 3649549735, 3378185726, 2507583628, 797259487, 2727140464,
        425385681, 312159665, 2458645191, 1992290670,
    ],
    [
        2797620941, 1278120289, 1583166048, 4198007656, 2699771394, 575188855,
        3278684196, 912646032, 1063563835, 2371048426, 48394205, 2888098417,
        1026659012, 3796614000, 832294306, 1306173205,
    ],
    [
        2446152743, 1383897571, 3914576163, 1904496024, 4275110371, 55368757,
        2173450832, 3724615507, 1082864998, 3806013653, 2147003797, 588066480,
        1572263549, 1751092705, 2778710800, 3795865646,
    ],
];

#[test]
fn prng_lane_draws_are_seed_stable() {
    let mut bank = StreamBank::new(LANE_SEED, 4);
    for step in 0..16 {
        let states = bank.step();
        for (lane, expect) in LANE_DRAWS.iter().enumerate() {
            assert_eq!(
                states[lane], expect[step],
                "lane {lane} diverged at step {step}: PRNG stream contract broken \
                 (seed {LANE_SEED:#010x})"
            );
        }
    }
}

/// Intensities probed by the chi-squared test, their per-run seeds, and
/// the exact spike totals the deterministic streams produce over
/// `CHI2_STEPS` timesteps × 784 pixels. The totals are themselves golden
/// values: any encoder change that alters a single draw breaks them.
const CHI2_STEPS: u32 = 96;
const CHI2_CASES: [(u8, u32); 5] = [
    (16, 4703),
    (64, 18779),
    (128, 37750),
    (200, 58790),
    (240, 70546),
];

#[test]
fn spike_rate_tracks_intensity_within_chi_squared_bound() {
    let trials = f64::from(CHI2_STEPS) * IMG_PIXELS as f64;
    let mut chi2_total = 0.0;
    for (intensity, pinned_total) in CHI2_CASES {
        let img = Image { label: 0, pixels: vec![intensity; IMG_PIXELS] };
        let seed = 0xBEEF_0000 + u32::from(intensity);
        let mut enc = PoissonEncoder::new(&img, seed);
        let mut spikes = 0u32;
        for _ in 0..CHI2_STEPS {
            spikes += enc.step().iter().filter(|&&s| s).count() as u32;
        }
        assert_eq!(
            spikes, pinned_total,
            "I={intensity}: exact spike total drifted (seed {seed:#010x})"
        );

        let p = f64::from(intensity) / 256.0;
        let mean = trials * p;
        let var = trials * p * (1.0 - p);
        let z2 = (f64::from(spikes) - mean).powi(2) / var;
        chi2_total += z2;

        let rate = f64::from(spikes) / trials;
        assert!(
            (rate - p).abs() < 0.01,
            "I={intensity}: spike rate {rate:.5} strays from {p:.5}"
        );
    }
    // 5 independent binomial cells ~ chi2(5): P(chi2 > 15) < 0.011, and the
    // pinned streams actually score ~0.89 — a real rate distortion (biased
    // comparator, lane shuffle, dropped draws) lands far above the bound.
    assert!(
        chi2_total < 15.0,
        "chi-squared statistic {chi2_total:.3} rejects the intensity/256 spike-rate law"
    );
}

#[test]
fn lanes_are_decorrelated_across_a_long_run() {
    // Adjacent lanes must not co-spike beyond chance: over the pinned
    // run at I=128 (p=0.5), the agreement rate between neighbouring
    // pixels' spike trains should hover near 0.5.
    let img = Image { label: 0, pixels: vec![128; IMG_PIXELS] };
    let mut enc = PoissonEncoder::new(&img, 0xBEEF_0080);
    let (mut agree, mut total) = (0u64, 0u64);
    for _ in 0..CHI2_STEPS {
        let step = enc.step();
        for pair in step.windows(2) {
            agree += u64::from(pair[0] == pair[1]);
            total += 1;
        }
    }
    let rate = agree as f64 / total as f64;
    assert!(
        (rate - 0.5).abs() < 0.01,
        "neighbouring lanes agree at {rate:.5} — streams are correlated"
    );
}
