//! Cross-language golden replay: the Python build path (jnp reference,
//! kernels) wrote encoder spike trains and LIF traces into the artifacts;
//! these tests replay the same seeds through the Rust behavioral model and
//! the cycle-accurate RTL core and demand bit-exact agreement. This is the
//! strongest evidence that L1 (Pallas), L2 (JAX) and L3 (Rust) implement
//! one architecture.

mod common;

use common::{artifacts_dir, Cursor};
use snn_rtl::config::PruneMode;
use snn_rtl::data::{codec, Image, IMG_PIXELS};
use snn_rtl::rtl::RtlCore;
use snn_rtl::snn::{BehavioralNet, PoissonEncoder};
use snn_rtl::SnnConfig;

/// Parsed SNNE file.
struct GoldenEncoder {
    seed: u32,
    timesteps: usize,
    image: Image,
    /// spikes[t][pixel]
    spikes: Vec<Vec<bool>>,
}

fn load_golden_encoder(dir: &std::path::Path) -> GoldenEncoder {
    let buf = std::fs::read(dir.join("golden_encoder.bin")).expect("golden_encoder.bin");
    let mut c = Cursor::new(&buf);
    assert_eq!(c.bytes(4), b"SNNE");
    assert_eq!(c.u32(), 1, "version");
    let seed = c.u32();
    let n_pixels = c.u32() as usize;
    let timesteps = c.u32() as usize;
    assert_eq!(n_pixels, IMG_PIXELS);
    let image =
        Image { label: 3, pixels: c.bytes(n_pixels).to_vec() };
    let stride = (n_pixels + 7) / 8;
    let mut spikes = Vec::with_capacity(timesteps);
    for _ in 0..timesteps {
        let packed = c.bytes(stride);
        spikes.push((0..n_pixels).map(|i| packed[i / 8] >> (i % 8) & 1 == 1).collect());
    }
    assert_eq!(c.pos, buf.len(), "trailing bytes");
    GoldenEncoder { seed, timesteps, image, spikes }
}

#[test]
fn encoder_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_golden_encoder(&dir);
    let mut enc = PoissonEncoder::new(&g.image, g.seed);
    for (t, expect) in g.spikes.iter().enumerate() {
        let got = enc.step();
        assert_eq!(
            &got, expect,
            "encoder spike divergence at timestep {t} (seed {:#x})",
            g.seed
        );
    }
    assert_eq!(g.timesteps, g.spikes.len());
}

/// Parsed SNNT file.
struct GoldenTrace {
    cfg: SnnConfig,
    seed: u32,
    image: Image,
    membranes: Vec<Vec<i32>>,
    fired: Vec<Vec<bool>>,
    currents: Vec<Vec<i32>>,
    counts: Vec<u32>,
}

fn load_golden_trace(dir: &std::path::Path) -> GoldenTrace {
    let buf = std::fs::read(dir.join("golden_trace.bin")).expect("golden_trace.bin");
    let mut c = Cursor::new(&buf);
    assert_eq!(c.bytes(4), b"SNNT");
    assert_eq!(c.u32(), 1, "version");
    let v_th = c.i32();
    let decay_shift = c.u32();
    let acc_bits = c.u32();
    let prune_after = c.u32();
    let timesteps = c.u32() as usize;
    let n = c.u32() as usize;
    let seed = c.u32();
    let image = Image { label: 3, pixels: c.bytes(IMG_PIXELS).to_vec() };
    let mut membranes = Vec::new();
    let mut fired = Vec::new();
    let mut currents = Vec::new();
    for _ in 0..timesteps {
        membranes.push((0..n).map(|_| c.i32()).collect());
        fired.push(c.bytes(n).iter().map(|&b| b == 1).collect());
        currents.push((0..n).map(|_| c.i32()).collect());
    }
    let counts = (0..n).map(|_| c.i32() as u32).collect();
    assert_eq!(c.pos, buf.len(), "trailing bytes");
    let cfg = SnnConfig {
        v_th,
        decay_shift,
        acc_bits,
        timesteps: timesteps as u32,
        prune: if prune_after == 0 {
            PruneMode::Off
        } else {
            PruneMode::AfterFires { after_spikes: prune_after }
        },
        ..SnnConfig::paper()
    };
    GoldenTrace { cfg, seed, image, membranes, fired, currents, counts }
}

#[test]
fn behavioral_model_matches_python_trace() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_golden_trace(&dir);
    let w = codec::load_weights(dir.join("weights.bin")).unwrap();
    let net = BehavioralNet::new(g.cfg.clone(), w.weights).unwrap();
    let (out, traces) = net.classify_traced(&g.image, g.seed, g.cfg.timesteps);
    for (t, trace) in traces.iter().enumerate() {
        assert_eq!(trace.membrane, g.membranes[t], "membrane diverges at step {t}");
        assert_eq!(trace.fired, g.fired[t], "fire pattern diverges at step {t}");
        assert_eq!(trace.input_current, g.currents[t], "current diverges at step {t}");
    }
    assert_eq!(out.spike_counts, g.counts, "final spike counts diverge");
}

#[test]
fn rtl_core_matches_python_trace() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_golden_trace(&dir);
    let w = codec::load_weights(dir.join("weights.bin")).unwrap();
    let mut core = RtlCore::new(g.cfg.clone(), w.weights).unwrap();
    let r = core.run(&g.image, g.seed).unwrap();
    assert_eq!(r.activity.saturations, 0);
    for t in 0..g.membranes.len() {
        assert_eq!(r.membrane_by_step[t], g.membranes[t], "membrane step {t}");
        assert_eq!(r.spikes_by_step[t], g.fired[t], "fires step {t}");
    }
    assert_eq!(r.spike_counts, g.counts);
}

#[test]
fn golden_image_is_the_canonical_test_sample() {
    // The golden image must be test-set position 3 (class 3, index 0) —
    // pins the dataset cross-language contract through a second route.
    let Some(dir) = artifacts_dir() else { return };
    let g = load_golden_encoder(&dir);
    let ds = codec::load_dataset(dir.join("digits_test.bin")).unwrap();
    assert_eq!(ds.images[3].label, 3);
    assert_eq!(g.image.pixels, ds.images[3].pixels);
    let rust_rendered = snn_rtl::data::render_digit(2, 3, 0).0;
    assert_eq!(g.image.pixels, rust_rendered.pixels);
}
