//! Cross-language golden replay: the Python build path (jnp reference,
//! kernels) wrote encoder spike trains and LIF traces into the artifacts;
//! these tests replay the same seeds through the Rust behavioral model and
//! the cycle-accurate RTL core and demand bit-exact agreement. This is the
//! strongest evidence that L1 (Pallas), L2 (JAX) and L3 (Rust) implement
//! one architecture.

mod common;

use common::{artifacts_dir, Cursor};
use snn_rtl::config::{FireMode, LayerParams, LeakMode, PruneMode};
use snn_rtl::data::{codec, Image, IMG_PIXELS};
use snn_rtl::fixed::{WeightMatrix, WeightStack};
use snn_rtl::rtl::RtlCore;
use snn_rtl::snn::{BehavioralNet, PoissonEncoder};
use snn_rtl::SnnConfig;

/// Parsed SNNE file.
struct GoldenEncoder {
    seed: u32,
    timesteps: usize,
    image: Image,
    /// spikes[t][pixel]
    spikes: Vec<Vec<bool>>,
}

fn load_golden_encoder(dir: &std::path::Path) -> GoldenEncoder {
    let buf = std::fs::read(dir.join("golden_encoder.bin")).expect("golden_encoder.bin");
    let mut c = Cursor::new(&buf);
    assert_eq!(c.bytes(4), b"SNNE");
    assert_eq!(c.u32(), 1, "version");
    let seed = c.u32();
    let n_pixels = c.u32() as usize;
    let timesteps = c.u32() as usize;
    assert_eq!(n_pixels, IMG_PIXELS);
    let image =
        Image { label: 3, pixels: c.bytes(n_pixels).to_vec() };
    let stride = (n_pixels + 7) / 8;
    let mut spikes = Vec::with_capacity(timesteps);
    for _ in 0..timesteps {
        let packed = c.bytes(stride);
        spikes.push((0..n_pixels).map(|i| packed[i / 8] >> (i % 8) & 1 == 1).collect());
    }
    assert_eq!(c.pos, buf.len(), "trailing bytes");
    GoldenEncoder { seed, timesteps, image, spikes }
}

#[test]
fn encoder_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_golden_encoder(&dir);
    let mut enc = PoissonEncoder::new(&g.image, g.seed);
    for (t, expect) in g.spikes.iter().enumerate() {
        let got = enc.step();
        assert_eq!(
            &got, expect,
            "encoder spike divergence at timestep {t} (seed {:#x})",
            g.seed
        );
    }
    assert_eq!(g.timesteps, g.spikes.len());
}

/// Parsed SNNT file.
struct GoldenTrace {
    cfg: SnnConfig,
    seed: u32,
    image: Image,
    membranes: Vec<Vec<i32>>,
    fired: Vec<Vec<bool>>,
    currents: Vec<Vec<i32>>,
    counts: Vec<u32>,
}

fn load_golden_trace(dir: &std::path::Path) -> GoldenTrace {
    let buf = std::fs::read(dir.join("golden_trace.bin")).expect("golden_trace.bin");
    let mut c = Cursor::new(&buf);
    assert_eq!(c.bytes(4), b"SNNT");
    assert_eq!(c.u32(), 1, "version");
    let v_th = c.i32();
    let decay_shift = c.u32();
    let acc_bits = c.u32();
    let prune_after = c.u32();
    let timesteps = c.u32() as usize;
    let n = c.u32() as usize;
    let seed = c.u32();
    let image = Image { label: 3, pixels: c.bytes(IMG_PIXELS).to_vec() };
    let mut membranes = Vec::new();
    let mut fired = Vec::new();
    let mut currents = Vec::new();
    for _ in 0..timesteps {
        membranes.push((0..n).map(|_| c.i32()).collect());
        fired.push(c.bytes(n).iter().map(|&b| b == 1).collect());
        currents.push((0..n).map(|_| c.i32()).collect());
    }
    let counts = (0..n).map(|_| c.i32() as u32).collect();
    assert_eq!(c.pos, buf.len(), "trailing bytes");
    let cfg = SnnConfig {
        v_th,
        decay_shift,
        acc_bits,
        timesteps: timesteps as u32,
        prune: if prune_after == 0 {
            PruneMode::Off
        } else {
            PruneMode::AfterFires { after_spikes: prune_after }
        },
        ..SnnConfig::paper()
    };
    GoldenTrace { cfg, seed, image, membranes, fired, currents, counts }
}

#[test]
fn behavioral_model_matches_python_trace() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_golden_trace(&dir);
    let w = codec::load_weights(dir.join("weights.bin")).unwrap();
    let net = BehavioralNet::new(g.cfg.clone(), w.weights).unwrap();
    let (out, traces) = net.classify_traced(&g.image, g.seed, g.cfg.timesteps);
    for (t, trace) in traces.iter().enumerate() {
        assert_eq!(trace.membrane, g.membranes[t], "membrane diverges at step {t}");
        assert_eq!(trace.fired, g.fired[t], "fire pattern diverges at step {t}");
        assert_eq!(trace.input_current, g.currents[t], "current diverges at step {t}");
    }
    assert_eq!(out.spike_counts, g.counts, "final spike counts diverge");
}

#[test]
fn rtl_core_matches_python_trace() {
    let Some(dir) = artifacts_dir() else { return };
    let g = load_golden_trace(&dir);
    let w = codec::load_weights(dir.join("weights.bin")).unwrap();
    let mut core = RtlCore::new(g.cfg.clone(), w.weights).unwrap();
    let r = core.run(&g.image, g.seed).unwrap();
    assert_eq!(r.activity.saturations, 0);
    for t in 0..g.membranes.len() {
        assert_eq!(r.membrane_by_step[t], g.membranes[t], "membrane step {t}");
        assert_eq!(r.spikes_by_step[t], g.fired[t], "fires step {t}");
    }
    assert_eq!(r.spike_counts, g.counts);
}

// ---------------------------------------------------------------------------
// Embedded golden vectors — pinned `run_fast` outputs
// ---------------------------------------------------------------------------
//
// Unlike the artifact-gated replays above, these fixtures are fully
// self-contained: images, weights and configs are closed-form, and the
// expected per-class spike counts, winner and cycle count are checked-in
// constants. Bit-exactness drift in the encoder, the LIF datapath, the
// pruning controller or the fast path's scheduling now fails loudly on
// every `cargo test`, instead of only when the property test happens to
// sample the broken region. The three configs each pin one policy axis:
// `fire` (Immediate mode), `leak` (PerRow scheduling), `prune`
// (AfterFires gating).
//
// If an *intentional* semantic change invalidates them, regenerate by
// printing the actual values from the assertion failures — every assert
// reports the full observed vector.

/// Closed-form fixture images: an ascending ramp, a descending ramp, and
/// a bright band over a dim background.
fn fixture_image(kind: &str) -> Image {
    let pixels = (0..IMG_PIXELS)
        .map(|i| match kind {
            "ramp" => ((i * 255) / 783) as u8,
            "rev" => (255 - (i * 255) / 783) as u8,
            "band" => {
                if (300..500).contains(&i) {
                    255
                } else {
                    30
                }
            }
            other => panic!("unknown fixture image {other}"),
        })
        .collect();
    Image { label: 0, pixels }
}

/// Closed-form fixture weights: +48 on the block diagonal (pixel block
/// `i/79` excites neuron `i/79`), deterministic small noise elsewhere.
fn fixture_weights() -> WeightMatrix {
    let data = (0..IMG_PIXELS * 10)
        .map(|k| {
            let (i, j) = (k / 10, k % 10);
            if i / 79 == j {
                48
            } else {
                ((i * 31 + j * 17) % 23) as i32 - 11
            }
        })
        .collect();
    WeightMatrix::from_rows(IMG_PIXELS, 10, 9, data).unwrap()
}

struct GoldenCase {
    config: &'static str,
    image: &'static str,
    seed: u32,
    counts: [u32; 10],
    winner: u8,
    cycles: u64,
}

fn fixture_config(name: &str) -> SnnConfig {
    let base = SnnConfig::paper().with_timesteps(8);
    match name {
        "fire" => base
            .with_v_th(6000)
            .with_fire_mode(FireMode::Immediate)
            .with_prune(PruneMode::AfterFires { after_spikes: 1 }),
        "leak" => base
            .with_v_th(200)
            .with_leak_mode(LeakMode::PerRow { row_len: 28 })
            .with_prune(PruneMode::Off),
        "prune" => base
            .with_v_th(800)
            .with_prune(PruneMode::AfterFires { after_spikes: 2 }),
        other => panic!("unknown fixture config {other}"),
    }
}

/// The pinned vectors. Generated from an independent reimplementation of
/// the documented architectural semantics (validated against the PRNG
/// golden values in `prng/mod.rs`), then frozen.
const GOLDEN_CASES: &[GoldenCase] = &[
    GoldenCase {
        config: "fire",
        image: "ramp",
        seed: 0x1111_2222,
        counts: [0, 0, 0, 1, 1, 1, 1, 1, 1, 1],
        winner: 3,
        cycles: 6288,
    },
    GoldenCase {
        config: "fire",
        image: "rev",
        seed: 0x3333_4444,
        counts: [1, 1, 1, 1, 1, 1, 1, 0, 0, 0],
        winner: 0,
        cycles: 6288,
    },
    GoldenCase {
        config: "fire",
        image: "band",
        seed: 0x5555_6666,
        counts: [0, 0, 0, 0, 1, 1, 1, 0, 0, 0],
        winner: 4,
        cycles: 6288,
    },
    GoldenCase {
        config: "leak",
        image: "ramp",
        seed: 0x1111_2222,
        counts: [0, 0, 0, 0, 6, 8, 8, 8, 8, 8],
        winner: 5,
        cycles: 6504,
    },
    GoldenCase {
        config: "leak",
        image: "rev",
        seed: 0x3333_4444,
        counts: [0, 0, 0, 4, 8, 8, 8, 7, 8, 0],
        winner: 4,
        cycles: 6504,
    },
    GoldenCase {
        config: "leak",
        image: "band",
        seed: 0x5555_6666,
        counts: [0, 0, 0, 0, 8, 8, 8, 1, 5, 8],
        winner: 4,
        cycles: 6504,
    },
    GoldenCase {
        config: "prune",
        image: "ramp",
        seed: 0x1111_2222,
        counts: [0, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        winner: 1,
        cycles: 6288,
    },
    GoldenCase {
        config: "prune",
        image: "rev",
        seed: 0x3333_4444,
        counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 0],
        winner: 0,
        cycles: 6288,
    },
    GoldenCase {
        config: "prune",
        image: "band",
        seed: 0x5555_6666,
        counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        winner: 0,
        cycles: 6288,
    },
];

#[test]
fn run_fast_matches_pinned_golden_vectors() {
    for case in GOLDEN_CASES {
        let cfg = fixture_config(case.config);
        let img = fixture_image(case.image);
        let mut core = RtlCore::new(cfg, fixture_weights()).unwrap();
        let r = core.run_fast(&img, case.seed).unwrap();
        let tag = format!("{}/{}", case.config, case.image);
        assert_eq!(
            r.spike_counts, case.counts,
            "{tag}: spike counts drifted from the pinned golden vector"
        );
        assert_eq!(r.class, case.winner, "{tag}: winner drifted");
        assert_eq!(r.cycles, case.cycles, "{tag}: cycle count drifted");
    }
}

#[test]
fn batched_run_fast_matches_pinned_golden_vectors() {
    // All nine single-layer fixtures through `run_fast_batch`, batching
    // each config's three images (distinct seeds) into ONE sweep: the
    // batched engine must reproduce every pinned constant — per-image
    // PRNG streams commute with batching.
    for config in ["fire", "leak", "prune"] {
        let cases: Vec<&GoldenCase> =
            GOLDEN_CASES.iter().filter(|c| c.config == config).collect();
        assert_eq!(cases.len(), 3);
        let images: Vec<Image> = cases.iter().map(|c| fixture_image(c.image)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = cases.iter().map(|c| c.seed).collect();
        let mut core = RtlCore::new(fixture_config(config), fixture_weights()).unwrap();
        let results = core
            .run_fast_batch(&refs, &seeds, snn_rtl::snn::EarlyExit::Off)
            .unwrap();
        for (case, r) in cases.iter().zip(&results) {
            let tag = format!("batched {}/{}", case.config, case.image);
            assert_eq!(r.spike_counts, case.counts, "{tag}: spike counts drifted");
            assert_eq!(r.class, case.winner, "{tag}: winner drifted");
            assert_eq!(r.cycles, case.cycles, "{tag}: cycle count drifted");
        }
    }
}

#[test]
fn cycle_path_matches_pinned_golden_vectors() {
    // The same constants through the cycle-stepped FSM: a drift that hits
    // only one engine is localized immediately.
    for case in GOLDEN_CASES {
        let cfg = fixture_config(case.config);
        let img = fixture_image(case.image);
        let mut core = RtlCore::new(cfg, fixture_weights()).unwrap();
        let r = core.run(&img, case.seed).unwrap();
        let tag = format!("{}/{}", case.config, case.image);
        assert_eq!(r.spike_counts, case.counts, "{tag}: cycle-path spike counts drifted");
        assert_eq!(r.class, case.winner, "{tag}: cycle-path winner drifted");
        assert_eq!(r.cycles, case.cycles, "{tag}: cycle-path cycle count drifted");
    }
}

#[test]
fn behavioral_model_matches_pinned_golden_vectors() {
    // The behavioral model implements the architectural contract
    // (EndOfStep firing, per-timestep leak) — the `prune` fixture config
    // is exactly that, so its constants pin the golden model too.
    for case in GOLDEN_CASES.iter().filter(|c| c.config == "prune") {
        let cfg = fixture_config(case.config);
        let img = fixture_image(case.image);
        let net = BehavioralNet::new(cfg.clone(), fixture_weights()).unwrap();
        let (out, _traces) = net.classify_traced(&img, case.seed, cfg.timesteps);
        let tag = format!("behavioral/{}", case.image);
        assert_eq!(out.spike_counts, case.counts, "{tag}: spike counts drifted");
        assert_eq!(out.class, case.winner, "{tag}: winner drifted");
    }
}

// ---------------------------------------------------------------------------
// Embedded 2-layer golden vectors — pinned layered `run_fast` outputs
// ---------------------------------------------------------------------------
//
// Same methodology as the single-layer fixtures above, for the
// `[784, 12, 10]` topology: closed-form images (shared with the cases
// above), a closed-form two-layer weight stack, and checked-in per-layer
// spike counts + winner + cycle count. The constants were generated from
// an independent Python transliteration of the documented architectural
// semantics that first reproduces all 9 single-layer fixtures bit-for-bit
// (validating the transliteration) and the pinned PRNG vectors, then was
// run on the layered schedule. The three configs pin the three layered
// schedule axes: `deep` (EndOfStep chaining), `deep_prune` (per-layer
// AfterFires gating), `deep_fire` (Immediate mid-walk fires feeding the
// next layer through the step accumulator).

/// Closed-form 2-layer fixture stack: layer 0 maps pixel block `i/66` to
/// hidden neuron `i/66` at +44 with deterministic noise elsewhere; layer 1
/// maps hidden `h` to output `h % 10` at +100 with noise elsewhere.
fn deep_fixture_stack() -> WeightStack {
    let w0 = (0..IMG_PIXELS * 12)
        .map(|k| {
            let (i, h) = (k / 12, k % 12);
            if i / 66 == h {
                44
            } else {
                ((i * 29 + h * 13) % 19) as i32 - 9
            }
        })
        .collect();
    let w1 = (0..12 * 10)
        .map(|k| {
            let (h, j) = (k / 10, k % 10);
            if j == h % 10 {
                100
            } else {
                ((h * 11 + j * 5) % 15) as i32 - 7
            }
        })
        .collect();
    WeightStack::from_layers(vec![
        WeightMatrix::from_rows(IMG_PIXELS, 12, 9, w0).unwrap(),
        WeightMatrix::from_rows(12, 10, 9, w1).unwrap(),
    ])
    .unwrap()
}

fn deep_fixture_config(name: &str) -> SnnConfig {
    let base = SnnConfig::paper().with_topology(vec![784, 12, 10]).with_timesteps(8);
    match name {
        "deep" => base.with_v_th(300).with_prune(PruneMode::Off),
        "deep_prune" => base.with_v_th(180).with_prune(PruneMode::AfterFires { after_spikes: 2 }),
        "deep_fire" => base
            .with_v_th(150)
            .with_fire_mode(FireMode::Immediate)
            .with_prune(PruneMode::AfterFires { after_spikes: 2 }),
        other => panic!("unknown deep fixture config {other}"),
    }
}

struct DeepGoldenCase {
    config: &'static str,
    image: &'static str,
    seed: u32,
    hidden_counts: [u32; 12],
    counts: [u32; 10],
    winner: u8,
    cycles: u64,
}

/// Cycle budget: per timestep the hidden walk costs 784+1+1 clocks and the
/// output walk 12+1+1, so 800 × 8 = 6400 for every case.
const DEEP_GOLDEN_CASES: &[DeepGoldenCase] = &[
    DeepGoldenCase {
        config: "deep",
        image: "ramp",
        seed: 0x1111_2222,
        hidden_counts: [2, 6, 8, 8, 8, 8, 8, 8, 8, 8, 8, 8],
        counts: [2, 3, 1, 2, 2, 1, 1, 1, 1, 1],
        winner: 1,
        cycles: 6400,
    },
    DeepGoldenCase {
        config: "deep",
        image: "rev",
        seed: 0x3333_4444,
        hidden_counts: [8, 8, 8, 8, 8, 8, 8, 8, 8, 7, 6, 0],
        counts: [3, 1, 1, 2, 1, 1, 2, 1, 1, 1],
        winner: 0,
        cycles: 6400,
    },
    DeepGoldenCase {
        config: "deep",
        image: "band",
        seed: 0x5555_6666,
        hidden_counts: [5, 3, 6, 5, 8, 8, 8, 8, 4, 4, 6, 4],
        counts: [2, 1, 1, 1, 1, 1, 1, 1, 0, 0],
        winner: 0,
        cycles: 6400,
    },
    DeepGoldenCase {
        config: "deep_prune",
        image: "ramp",
        seed: 0x1111_2222,
        hidden_counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        counts: [1, 2, 0, 0, 0, 0, 0, 0, 0, 0],
        winner: 1,
        cycles: 6400,
    },
    DeepGoldenCase {
        config: "deep_prune",
        image: "rev",
        seed: 0x3333_4444,
        hidden_counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 1],
        counts: [2, 1, 0, 0, 0, 0, 0, 0, 0, 0],
        winner: 0,
        cycles: 6400,
    },
    DeepGoldenCase {
        config: "deep_prune",
        image: "band",
        seed: 0x5555_6666,
        hidden_counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        counts: [2, 1, 0, 0, 0, 0, 0, 0, 0, 0],
        winner: 0,
        cycles: 6400,
    },
    DeepGoldenCase {
        config: "deep_fire",
        image: "ramp",
        seed: 0x1111_2222,
        hidden_counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        counts: [1, 1, 0, 0, 0, 0, 0, 0, 0, 0],
        winner: 0,
        cycles: 6400,
    },
    DeepGoldenCase {
        config: "deep_fire",
        image: "rev",
        seed: 0x3333_4444,
        hidden_counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        counts: [1, 1, 0, 0, 0, 0, 0, 0, 0, 0],
        winner: 0,
        cycles: 6400,
    },
    DeepGoldenCase {
        config: "deep_fire",
        image: "band",
        seed: 0x5555_6666,
        hidden_counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        counts: [1, 2, 0, 1, 0, 0, 0, 0, 0, 1],
        winner: 1,
        cycles: 6400,
    },
];

#[test]
fn deep_run_fast_matches_pinned_golden_vectors() {
    for case in DEEP_GOLDEN_CASES {
        let cfg = deep_fixture_config(case.config);
        let img = fixture_image(case.image);
        let mut core = RtlCore::new(cfg, deep_fixture_stack()).unwrap();
        let r = core.run_fast(&img, case.seed).unwrap();
        let tag = format!("{}/{}", case.config, case.image);
        assert_eq!(
            r.spike_counts_by_layer[0], case.hidden_counts,
            "{tag}: hidden-layer spike counts drifted"
        );
        assert_eq!(
            r.spike_counts, case.counts,
            "{tag}: output spike counts drifted from the pinned golden vector"
        );
        assert_eq!(r.class, case.winner, "{tag}: winner drifted");
        assert_eq!(r.cycles, case.cycles, "{tag}: cycle count drifted");
    }
}

#[test]
fn batched_deep_run_fast_matches_pinned_golden_vectors() {
    // The nine 2-layer fixtures through the batched layered schedule —
    // per-layer counts included, so the batched inter-layer hand-off
    // masks are pinned too.
    for config in ["deep", "deep_prune", "deep_fire"] {
        let cases: Vec<&DeepGoldenCase> =
            DEEP_GOLDEN_CASES.iter().filter(|c| c.config == config).collect();
        assert_eq!(cases.len(), 3);
        let images: Vec<Image> = cases.iter().map(|c| fixture_image(c.image)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = cases.iter().map(|c| c.seed).collect();
        let mut core =
            RtlCore::new(deep_fixture_config(config), deep_fixture_stack()).unwrap();
        let results = core
            .run_fast_batch(&refs, &seeds, snn_rtl::snn::EarlyExit::Off)
            .unwrap();
        for (case, r) in cases.iter().zip(&results) {
            let tag = format!("batched {}/{}", case.config, case.image);
            assert_eq!(
                r.spike_counts_by_layer[0], case.hidden_counts,
                "{tag}: hidden counts drifted"
            );
            assert_eq!(r.spike_counts, case.counts, "{tag}: output counts drifted");
            assert_eq!(r.class, case.winner, "{tag}: winner drifted");
            assert_eq!(r.cycles, case.cycles, "{tag}: cycle count drifted");
        }
    }
}

#[test]
fn deep_cycle_path_matches_pinned_golden_vectors() {
    // The same constants through the cycle-stepped layered FSM: a drift
    // that hits only one engine is localized immediately.
    for case in DEEP_GOLDEN_CASES {
        let cfg = deep_fixture_config(case.config);
        let img = fixture_image(case.image);
        let mut core = RtlCore::new(cfg, deep_fixture_stack()).unwrap();
        let r = core.run(&img, case.seed).unwrap();
        let tag = format!("{}/{}", case.config, case.image);
        assert_eq!(
            r.spike_counts_by_layer[0], case.hidden_counts,
            "{tag}: cycle-path hidden counts drifted"
        );
        assert_eq!(r.spike_counts, case.counts, "{tag}: cycle-path output counts drifted");
        assert_eq!(r.class, case.winner, "{tag}: cycle-path winner drifted");
        assert_eq!(r.cycles, case.cycles, "{tag}: cycle-path cycle count drifted");
    }
}

#[test]
fn deep_behavioral_model_matches_pinned_golden_vectors() {
    // The chained behavioral stack implements the architectural contract
    // (EndOfStep firing, per-timestep leak) — the `deep` and `deep_prune`
    // configs are exactly that, so their constants pin the golden model's
    // layer chaining too.
    for case in DEEP_GOLDEN_CASES.iter().filter(|c| c.config != "deep_fire") {
        let cfg = deep_fixture_config(case.config);
        let img = fixture_image(case.image);
        let net = BehavioralNet::new(cfg, deep_fixture_stack()).unwrap();
        let out = net.classify(&img, case.seed);
        let tag = format!("behavioral-{}/{}", case.config, case.image);
        assert_eq!(out.spike_counts, case.counts, "{tag}: spike counts drifted");
        assert_eq!(out.class, case.winner, "{tag}: winner drifted");
    }
}

#[test]
fn weight_stack_artifact_roundtrip_preserves_deep_fixture() {
    // The multi-layer artifact format (SNNW v2) must round-trip the 2-layer
    // fixture stack bit-for-bit, and the reloaded stack must reproduce a
    // pinned golden case through the RTL core.
    let dir = std::env::temp_dir().join(format!("snn_golden_stack_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("weights_stack.bin");
    let art = codec::WeightStackArtifact {
        stack: deep_fixture_stack(),
        v_th: 300,
        decay_shift: 3,
        timesteps: 8,
        prune_after: 0,
        layer_params: Vec::new(),
        sparse_threshold: None,
    };
    codec::save_weight_stack(&path, &art).unwrap();
    let back = codec::load_weight_stack(&path).unwrap();
    assert_eq!(back, art, "stack artifact round-trip drifted");
    assert_eq!(back.config().topology, vec![784, 12, 10]);

    let case = &DEEP_GOLDEN_CASES[0]; // deep/ramp
    let cfg = deep_fixture_config(case.config);
    let mut core = RtlCore::new(cfg, back.stack).unwrap();
    let r = core.run_fast(&fixture_image(case.image), case.seed).unwrap();
    assert_eq!(r.spike_counts, case.counts, "reloaded stack diverges from golden");
    assert_eq!(r.class, case.winner);
}

// ---------------------------------------------------------------------------
// Embedded heterogeneous per-layer golden vectors — pinned 3-layer outputs
// ---------------------------------------------------------------------------
//
// Same methodology as the fixtures above, for the `[784, 14, 12, 10]`
// topology with *distinct* per-layer parameters: layer 0 fires at 260
// (decay 3, prune after 2), layer 1 at 120 (decay 2, prune after 1),
// layer 2 at 40 (decay 4, pruning off). The scalar defaults are set to
// values no layer uses (`v_th 999`, `decay 5`, `prune after 7`), so any
// code path that falls back to the shared scalars instead of the
// per-layer resolution drifts loudly. Constants were generated from the
// Python transliteration in `tools/gen_golden_fixtures.py`, which first
// reproduces all 18 pre-existing fixtures bit-for-bit (validating the
// transliteration) before emitting these. Two configs pin the two
// schedule modes: `hetero` (EndOfStep — also cross-checked against the
// behavioral stack) and `hetero_fire` (Immediate mid-walk fires).

/// Closed-form 3-layer fixture stack: block diagonals at +42/+90/+70 with
/// deterministic small noise elsewhere (mirrored in the generator).
fn hetero_fixture_stack() -> WeightStack {
    let w0 = (0..IMG_PIXELS * 14)
        .map(|k| {
            let (i, h) = (k / 14, k % 14);
            if i / 56 == h {
                42
            } else {
                ((i * 23 + h * 7) % 17) as i32 - 8
            }
        })
        .collect();
    let w1 = (0..14 * 12)
        .map(|k| {
            let (h, m) = (k / 12, k % 12);
            if m == h % 12 {
                90
            } else {
                ((h * 13 + m * 3) % 11) as i32 - 5
            }
        })
        .collect();
    let w2 = (0..12 * 10)
        .map(|k| {
            let (m, j) = (k / 10, k % 10);
            if j == m % 10 {
                70
            } else {
                ((m * 7 + j * 11) % 13) as i32 - 6
            }
        })
        .collect();
    WeightStack::from_layers(vec![
        WeightMatrix::from_rows(IMG_PIXELS, 14, 9, w0).unwrap(),
        WeightMatrix::from_rows(14, 12, 9, w1).unwrap(),
        WeightMatrix::from_rows(12, 10, 9, w2).unwrap(),
    ])
    .unwrap()
}

fn hetero_fixture_config(name: &str) -> SnnConfig {
    let base = SnnConfig::paper()
        .with_topology(vec![784, 14, 12, 10])
        .with_timesteps(8)
        // Deliberately unused scalars: every layer overrides all three.
        .with_v_th(999)
        .with_decay_shift(5)
        .with_prune(PruneMode::AfterFires { after_spikes: 7 })
        .with_layer_params(vec![
            LayerParams {
                v_th: Some(260),
                decay_shift: Some(3),
                prune: Some(PruneMode::AfterFires { after_spikes: 2 }),
            },
            LayerParams {
                v_th: Some(120),
                decay_shift: Some(2),
                prune: Some(PruneMode::AfterFires { after_spikes: 1 }),
            },
            LayerParams { v_th: Some(40), decay_shift: Some(4), prune: Some(PruneMode::Off) },
        ]);
    match name {
        "hetero" => base,
        "hetero_fire" => base.with_fire_mode(FireMode::Immediate),
        other => panic!("unknown hetero fixture config {other}"),
    }
}

struct HeteroGoldenCase {
    config: &'static str,
    image: &'static str,
    seed: u32,
    l0_counts: [u32; 14],
    l1_counts: [u32; 12],
    counts: [u32; 10],
    winner: u8,
    cycles: u64,
}

/// Cycle budget: (784+1+1) + (14+1+1) + (12+1+1) = 816 clocks per
/// timestep, 6528 over the 8-step window for every case.
const HETERO_GOLDEN_CASES: &[HeteroGoldenCase] = &[
    HeteroGoldenCase {
        config: "hetero",
        image: "ramp",
        seed: 0x1111_2222,
        l0_counts: [1, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        l1_counts: [1, 1, 0, 0, 0, 1, 0, 1, 0, 1, 0, 1],
        counts: [1, 2, 0, 0, 0, 1, 0, 1, 0, 1],
        winner: 1,
        cycles: 6528,
    },
    HeteroGoldenCase {
        config: "hetero",
        image: "rev",
        seed: 0x3333_4444,
        l0_counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 1],
        l1_counts: [1, 0, 0, 1, 0, 1, 1, 1, 0, 1, 1, 0],
        counts: [1, 0, 0, 1, 0, 1, 1, 1, 0, 1],
        winner: 0,
        cycles: 6528,
    },
    HeteroGoldenCase {
        config: "hetero",
        image: "band",
        seed: 0x5555_6666,
        l0_counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        l1_counts: [1, 1, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0],
        counts: [1, 1, 0, 0, 0, 1, 0, 0, 0, 0],
        winner: 0,
        cycles: 6528,
    },
    HeteroGoldenCase {
        config: "hetero_fire",
        image: "ramp",
        seed: 0x1111_2222,
        l0_counts: [1, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        l1_counts: [0, 1, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0],
        counts: [0, 1, 1, 0, 0, 0, 0, 0, 0, 0],
        winner: 1,
        cycles: 6528,
    },
    HeteroGoldenCase {
        config: "hetero_fire",
        image: "rev",
        seed: 0x3333_4444,
        l0_counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 1],
        l1_counts: [1, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0],
        counts: [1, 0, 0, 0, 0, 0, 0, 0, 0, 0],
        winner: 0,
        cycles: 6528,
    },
    HeteroGoldenCase {
        config: "hetero_fire",
        image: "band",
        seed: 0x5555_6666,
        l0_counts: [2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        l1_counts: [1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 1, 1],
        counts: [2, 2, 1, 1, 1, 0, 0, 0, 0, 0],
        winner: 0,
        cycles: 6528,
    },
];

#[test]
fn hetero_run_fast_matches_pinned_golden_vectors() {
    for case in HETERO_GOLDEN_CASES {
        let cfg = hetero_fixture_config(case.config);
        let img = fixture_image(case.image);
        let mut core = RtlCore::new(cfg, hetero_fixture_stack()).unwrap();
        let r = core.run_fast(&img, case.seed).unwrap();
        let tag = format!("{}/{}", case.config, case.image);
        assert_eq!(
            r.spike_counts_by_layer[0], case.l0_counts,
            "{tag}: layer-0 spike counts drifted"
        );
        assert_eq!(
            r.spike_counts_by_layer[1], case.l1_counts,
            "{tag}: layer-1 spike counts drifted"
        );
        assert_eq!(r.spike_counts, case.counts, "{tag}: output counts drifted");
        assert_eq!(r.class, case.winner, "{tag}: winner drifted");
        assert_eq!(r.cycles, case.cycles, "{tag}: cycle count drifted");
    }
}

#[test]
fn batched_hetero_run_fast_matches_pinned_golden_vectors() {
    // The six heterogeneous 3-layer fixtures through the batched path —
    // with these, all 24 embedded golden fixtures anchor
    // `run_fast_batch`: per-layer parameter resolution must batch
    // identically under both fire modes.
    for config in ["hetero", "hetero_fire"] {
        let cases: Vec<&HeteroGoldenCase> =
            HETERO_GOLDEN_CASES.iter().filter(|c| c.config == config).collect();
        assert_eq!(cases.len(), 3);
        let images: Vec<Image> = cases.iter().map(|c| fixture_image(c.image)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = cases.iter().map(|c| c.seed).collect();
        let mut core =
            RtlCore::new(hetero_fixture_config(config), hetero_fixture_stack()).unwrap();
        let results = core
            .run_fast_batch(&refs, &seeds, snn_rtl::snn::EarlyExit::Off)
            .unwrap();
        for (case, r) in cases.iter().zip(&results) {
            let tag = format!("batched {}/{}", case.config, case.image);
            assert_eq!(r.spike_counts_by_layer[0], case.l0_counts, "{tag}: layer 0");
            assert_eq!(r.spike_counts_by_layer[1], case.l1_counts, "{tag}: layer 1");
            assert_eq!(r.spike_counts, case.counts, "{tag}: output counts");
            assert_eq!(r.class, case.winner, "{tag}: winner");
            assert_eq!(r.cycles, case.cycles, "{tag}: cycle count");
        }
    }
}

#[test]
fn sparse_sweep_matches_all_pinned_golden_vectors() {
    // All 24 embedded fixtures (9 single-layer, 9 two-layer, 6
    // heterogeneous 3-layer) re-anchored through the event-driven sparse
    // sweep at magnitude threshold 0: the CSR image keeps every entry, so
    // `run_fast_sparse` must reproduce not just the pinned constants but
    // the *entire* dense `run_fast` result — per-step logs, per-layer
    // activity, energy — bit for bit.
    let run_both = |cfg: SnnConfig, stack: WeightStack, img: &Image, seed: u32| {
        let mut dense = RtlCore::new(cfg.clone(), stack.clone()).unwrap();
        let want = dense.run_fast(img, seed).unwrap();
        let mut sparse = RtlCore::new(cfg, stack).unwrap();
        sparse.attach_sparse(0);
        assert_eq!(sparse.sparse_density(), Some(1.0));
        let got = sparse.run_fast_sparse(img, seed).unwrap();
        assert_eq!(got, want, "sparse sweep diverges from dense at threshold 0");
        got
    };
    for case in GOLDEN_CASES {
        let r = run_both(
            fixture_config(case.config),
            fixture_weights().into(),
            &fixture_image(case.image),
            case.seed,
        );
        let tag = format!("sparse {}/{}", case.config, case.image);
        assert_eq!(r.spike_counts, case.counts, "{tag}: counts drifted");
        assert_eq!(r.class, case.winner, "{tag}: winner drifted");
        assert_eq!(r.cycles, case.cycles, "{tag}: cycle count drifted");
    }
    for case in DEEP_GOLDEN_CASES {
        let r = run_both(
            deep_fixture_config(case.config),
            deep_fixture_stack(),
            &fixture_image(case.image),
            case.seed,
        );
        let tag = format!("sparse {}/{}", case.config, case.image);
        assert_eq!(r.spike_counts_by_layer[0], case.hidden_counts, "{tag}: hidden counts");
        assert_eq!(r.spike_counts, case.counts, "{tag}: counts drifted");
        assert_eq!(r.class, case.winner, "{tag}: winner drifted");
        assert_eq!(r.cycles, case.cycles, "{tag}: cycle count drifted");
    }
    for case in HETERO_GOLDEN_CASES {
        let r = run_both(
            hetero_fixture_config(case.config),
            hetero_fixture_stack(),
            &fixture_image(case.image),
            case.seed,
        );
        let tag = format!("sparse {}/{}", case.config, case.image);
        assert_eq!(r.spike_counts_by_layer[0], case.l0_counts, "{tag}: layer 0");
        assert_eq!(r.spike_counts_by_layer[1], case.l1_counts, "{tag}: layer 1");
        assert_eq!(r.spike_counts, case.counts, "{tag}: counts drifted");
        assert_eq!(r.class, case.winner, "{tag}: winner drifted");
        assert_eq!(r.cycles, case.cycles, "{tag}: cycle count drifted");
    }
}

#[test]
fn batched_sparse_sweep_matches_pinned_golden_vectors() {
    // The batched sparse arm over the 2-layer fixtures: each config's
    // three images in ONE CSR-driven sweep must reproduce the pinned
    // constants (and the per-layer hand-off masks they imply).
    for config in ["deep", "deep_prune", "deep_fire"] {
        let cases: Vec<&DeepGoldenCase> =
            DEEP_GOLDEN_CASES.iter().filter(|c| c.config == config).collect();
        let images: Vec<Image> = cases.iter().map(|c| fixture_image(c.image)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = cases.iter().map(|c| c.seed).collect();
        let mut core =
            RtlCore::new(deep_fixture_config(config), deep_fixture_stack()).unwrap();
        core.attach_sparse(0);
        let results = core
            .run_fast_batch_sparse(&refs, &seeds, snn_rtl::snn::EarlyExit::Off)
            .unwrap();
        for (case, r) in cases.iter().zip(&results) {
            let tag = format!("batched-sparse {}/{}", case.config, case.image);
            assert_eq!(r.spike_counts_by_layer[0], case.hidden_counts, "{tag}: hidden");
            assert_eq!(r.spike_counts, case.counts, "{tag}: output counts");
            assert_eq!(r.class, case.winner, "{tag}: winner");
            assert_eq!(r.cycles, case.cycles, "{tag}: cycle count");
        }
    }
}

#[test]
fn batched_behavioral_matches_pinned_golden_vectors() {
    // The batched behavioral engine against the architectural-contract
    // fixtures (EndOfStep + per-timestep leak): `prune`, `deep`,
    // `deep_prune` and `hetero` constants all reproduce through ONE
    // `classify_batch_with` pass per config.
    use snn_rtl::snn::EarlyExit;
    {
        let cases: Vec<&GoldenCase> =
            GOLDEN_CASES.iter().filter(|c| c.config == "prune").collect();
        let images: Vec<Image> = cases.iter().map(|c| fixture_image(c.image)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = cases.iter().map(|c| c.seed).collect();
        let cfg = fixture_config("prune");
        let net = BehavioralNet::new(cfg.clone(), fixture_weights()).unwrap();
        let mut batch = net.batch_prototype();
        let outs = net
            .classify_batch_with(&mut batch, &refs, &seeds, cfg.timesteps, EarlyExit::Off)
            .unwrap();
        for (case, out) in cases.iter().zip(&outs) {
            let tag = format!("batched-behavioral {}/{}", case.config, case.image);
            assert_eq!(out.spike_counts, case.counts, "{tag}: counts drifted");
            assert_eq!(out.class, case.winner, "{tag}: winner drifted");
        }
    }
    for config in ["deep", "deep_prune"] {
        let cases: Vec<&DeepGoldenCase> =
            DEEP_GOLDEN_CASES.iter().filter(|c| c.config == config).collect();
        let images: Vec<Image> = cases.iter().map(|c| fixture_image(c.image)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = cases.iter().map(|c| c.seed).collect();
        let cfg = deep_fixture_config(config);
        let net = BehavioralNet::new(cfg.clone(), deep_fixture_stack()).unwrap();
        let mut batch = net.batch_prototype();
        let outs = net
            .classify_batch_with(&mut batch, &refs, &seeds, cfg.timesteps, EarlyExit::Off)
            .unwrap();
        for (case, out) in cases.iter().zip(&outs) {
            let tag = format!("batched-behavioral {}/{}", case.config, case.image);
            assert_eq!(out.spike_counts, case.counts, "{tag}: counts drifted");
            assert_eq!(out.class, case.winner, "{tag}: winner drifted");
        }
    }
    {
        let cases: Vec<&HeteroGoldenCase> =
            HETERO_GOLDEN_CASES.iter().filter(|c| c.config == "hetero").collect();
        let images: Vec<Image> = cases.iter().map(|c| fixture_image(c.image)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = cases.iter().map(|c| c.seed).collect();
        let cfg = hetero_fixture_config("hetero");
        let net = BehavioralNet::new(cfg.clone(), hetero_fixture_stack()).unwrap();
        let mut batch = net.batch_prototype();
        let outs = net
            .classify_batch_with(&mut batch, &refs, &seeds, cfg.timesteps, EarlyExit::Off)
            .unwrap();
        for (case, out) in cases.iter().zip(&outs) {
            let tag = format!("batched-behavioral hetero/{}", case.image);
            assert_eq!(out.spike_counts, case.counts, "{tag}: counts drifted");
            assert_eq!(out.class, case.winner, "{tag}: winner drifted");
        }
    }
}

#[test]
fn hetero_cycle_path_matches_pinned_golden_vectors() {
    // The same constants through the cycle-stepped FSM: a per-layer
    // parameter drift that hits only one engine is localized immediately.
    for case in HETERO_GOLDEN_CASES {
        let cfg = hetero_fixture_config(case.config);
        let img = fixture_image(case.image);
        let mut core = RtlCore::new(cfg, hetero_fixture_stack()).unwrap();
        let r = core.run(&img, case.seed).unwrap();
        let tag = format!("{}/{}", case.config, case.image);
        assert_eq!(r.spike_counts_by_layer[0], case.l0_counts, "{tag}: cycle-path layer 0");
        assert_eq!(r.spike_counts_by_layer[1], case.l1_counts, "{tag}: cycle-path layer 1");
        assert_eq!(r.spike_counts, case.counts, "{tag}: cycle-path output counts");
        assert_eq!(r.class, case.winner, "{tag}: cycle-path winner");
        assert_eq!(r.cycles, case.cycles, "{tag}: cycle-path cycle count");
    }
}

#[test]
fn hetero_behavioral_model_matches_pinned_golden_vectors() {
    // The chained behavioral stack implements the architectural contract
    // (EndOfStep firing, per-timestep leak) — the `hetero` config is
    // exactly that, so its constants pin the behavioral per-layer
    // resolution too (the third engine cross-check).
    for case in HETERO_GOLDEN_CASES.iter().filter(|c| c.config == "hetero") {
        let cfg = hetero_fixture_config(case.config);
        let img = fixture_image(case.image);
        let net = BehavioralNet::new(cfg, hetero_fixture_stack()).unwrap();
        let out = net.classify(&img, case.seed);
        let tag = format!("behavioral-{}/{}", case.config, case.image);
        assert_eq!(out.spike_counts, case.counts, "{tag}: spike counts drifted");
        assert_eq!(out.class, case.winner, "{tag}: winner drifted");
    }
}

#[test]
fn hetero_stack_artifact_roundtrips_through_snnw_v3() {
    // The v3 per-layer parameter block must round-trip the heterogeneous
    // calibration, and the reloaded config must reproduce a pinned case.
    let dir = std::env::temp_dir().join(format!("snn_golden_hetero_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("weights_hetero.bin");
    let cfg = hetero_fixture_config("hetero");
    let art = codec::WeightStackArtifact {
        stack: hetero_fixture_stack(),
        v_th: cfg.v_th,
        decay_shift: cfg.decay_shift,
        timesteps: cfg.timesteps,
        prune_after: 7,
        layer_params: cfg.layer_params.clone(),
        sparse_threshold: None,
    };
    codec::save_weight_stack(&path, &art).unwrap();
    let back = codec::load_weight_stack(&path).unwrap();
    assert_eq!(back.layer_params, art.layer_params, "v3 param block drifted");

    let case = &HETERO_GOLDEN_CASES[0]; // hetero/ramp
    // The artifact's config (scalars + v3 block + paper scheduling
    // defaults) is exactly the fixture's EndOfStep config.
    let mut core = RtlCore::new(back.config(), back.stack).unwrap();
    let r = core.run_fast(&fixture_image(case.image), case.seed).unwrap();
    assert_eq!(r.spike_counts, case.counts, "reloaded v3 config diverges from golden");
    assert_eq!(r.class, case.winner);
}

#[test]
fn golden_image_is_the_canonical_test_sample() {
    // The golden image must be test-set position 3 (class 3, index 0) —
    // pins the dataset cross-language contract through a second route.
    let Some(dir) = artifacts_dir() else { return };
    let g = load_golden_encoder(&dir);
    let ds = codec::load_dataset(dir.join("digits_test.bin")).unwrap();
    assert_eq!(ds.images[3].label, 3);
    assert_eq!(g.image.pixels, ds.images[3].pixels);
    let rust_rendered = snn_rtl::data::render_digit(2, 3, 0).0;
    assert_eq!(g.image.pixels, rust_rendered.pixels);
}
