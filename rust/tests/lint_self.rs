//! pallas-lint self-tests: the analyzer must fire on every embedded
//! known-bad fixture at exactly the `EXPECT:Lx`-pinned lines, and the
//! real tree must be clean. Together these pin both directions of the
//! lint — no silent rule rot, no accumulated violations.

use std::collections::BTreeSet;
use std::path::Path;

use snn_rtl::lint::{self, Rule};

fn render(findings: &[lint::Finding]) -> String {
    findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
}

#[test]
fn fixtures_fire_at_pinned_lines() {
    for (path, src) in lint::fixtures() {
        let analysis = lint::analyze_files([(path, src)]);
        let got: BTreeSet<(usize, Rule)> =
            analysis.findings.iter().map(|f| (f.line, f.rule)).collect();
        let want: BTreeSet<(usize, Rule)> = lint::expected_findings(src).into_iter().collect();
        assert_eq!(
            got,
            want,
            "fixture {path} findings diverge from its EXPECT markers; got:\n{}",
            render(&analysis.findings)
        );
        assert!(!want.is_empty(), "fixture {path} pins no findings — dead fixture");
    }
}

#[test]
fn fixtures_cover_every_rule() {
    let mut rules: BTreeSet<Rule> = BTreeSet::new();
    for (_, src) in lint::fixtures() {
        for (_, r) in lint::expected_findings(src) {
            rules.insert(r);
        }
    }
    for r in [Rule::L1, Rule::L2, Rule::L3, Rule::L4, Rule::L5] {
        assert!(rules.contains(&r), "no fixture exercises rule {}", r.id());
    }
}

#[test]
// Walks the whole source tree from disk: needs fs access (blocked by Miri's
// isolation) and interprets ~25k lines of lexing, so keep it off the Miri
// smoke tier.
#[cfg_attr(miri, ignore)]
fn real_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let analysis = lint::analyze_tree(root).expect("walk rust/src + rust/tests");
    // Guard against a broken walk silently passing on zero files.
    assert!(
        analysis.files >= 40,
        "suspiciously small walk ({} files) — did the tree layout move?",
        analysis.files
    );
    assert!(
        analysis.findings.is_empty(),
        "pallas-lint findings on the real tree:\n{}",
        render(&analysis.findings)
    );
}
