//! Miri smoke tier: a deliberately tiny test set that CI runs under the
//! Miri interpreter (see `.github/workflows/ci.yml`, `miri` job) to check
//! the crate's core invariants for undefined behaviour — unchecked
//! arithmetic, out-of-bounds indexing, invalid `char` boundary slicing,
//! and data races in the metrics counters.
//!
//! Every test here is named `miri_smoke_*` so the job can filter on the
//! prefix, and each one is sized for an interpreter that runs two to
//! three orders of magnitude slower than native code: small inputs, few
//! iterations, no filesystem access (Miri's isolation blocks it).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use snn_rtl::coordinator::ServerMetrics;
use snn_rtl::fixed::{leak, sat_add, sat_clamp};
use snn_rtl::lint;
use snn_rtl::prng::{splitmix32, xorshift32_step, Xorshift32};

#[test]
fn miri_smoke_prng_streams() {
    // The raw step function never reaches the zero fixed point from a
    // nonzero state, and the seeded generator is deterministic.
    let mut s = 0xDEAD_BEEFu32;
    for _ in 0..64 {
        s = xorshift32_step(s);
        assert_ne!(s, 0);
    }
    let a: Vec<u32> = {
        let mut g = Xorshift32::new(7);
        (0..16).map(|_| g.next_u32()).collect()
    };
    let b: Vec<u32> = {
        let mut g = Xorshift32::new(7);
        (0..16).map(|_| g.next_u32()).collect()
    };
    assert_eq!(a, b);
    assert_ne!(splitmix32(0), splitmix32(1));
}

#[test]
fn miri_smoke_fixed_saturation() {
    // The saturation funnels clamp to the symmetric `bits`-wide range at
    // both extremes — the exact spots where unchecked adds would be UB.
    let max = (1i32 << 15) - 1;
    assert_eq!(sat_add(max, 1, 16), max);
    assert_eq!(sat_add(-max, -1, 16), -max);
    assert_eq!(sat_add(100, -42, 16), 58);
    assert_eq!(sat_clamp(i64::MAX, 16), max);
    assert_eq!(sat_clamp(i64::MIN, 16), -max);
    assert_eq!(leak(-1, 4), 0);
    assert_eq!(leak(256, 4), 240);
}

#[test]
fn miri_smoke_lint_lexer() {
    // The pallas-lint lexer does byte-indexed scanning with manual char
    // boundary handling — run one embedded fixture end-to-end under the
    // interpreter to prove the slicing is sound.
    let (path, src) = lint::fixtures()[0];
    let analysis = lint::analyze_files([(path, src)]);
    assert_eq!(analysis.findings.len(), lint::expected_findings(src).len());
}

#[test]
fn miri_smoke_metrics_conservation() {
    // Two writers bump submitted→completed with Release increments while
    // the main thread snapshots concurrently; the Acquire snapshot must
    // keep `submitted >= completed + failed + shed` in every interleaving
    // Miri explores.
    let metrics = Arc::new(ServerMetrics::default());
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let m = Arc::clone(&metrics);
            std::thread::spawn(move || {
                for _ in 0..32 {
                    m.submitted.fetch_add(1, Ordering::Release);
                    m.completed.fetch_add(1, Ordering::Release);
                }
            })
        })
        .collect();
    for _ in 0..16 {
        let snap = metrics.snapshot();
        assert!(snap.submitted >= snap.completed + snap.failed + snap.shed);
    }
    for w in workers {
        w.join().unwrap();
    }
    let quiesced = metrics.snapshot();
    assert_eq!(quiesced.submitted, 64);
    assert_eq!(quiesced.completed, 64);
}
