//! Live PJRT round-trip: the AOT-compiled JAX/Pallas executables must
//! agree bit-for-bit with the Rust behavioral model — the runtime half of
//! the three-layer equivalence story.

mod common;

use common::artifacts_dir;
use snn_rtl::ann::Mlp;
use snn_rtl::data::{codec, DigitGen, Image};
use snn_rtl::runtime::XlaSnn;
use snn_rtl::snn::BehavioralNet;

fn load_stack() -> Option<(XlaSnn, BehavioralNet, Vec<Image>)> {
    let dir = artifacts_dir()?;
    // Builds without the `xla` feature stub the runtime out; its load
    // always errs even when artifacts exist, so treat that as a skip
    // rather than a failure (mirrors benches/backends.rs).
    let snn = match XlaSnn::load(&dir) {
        Ok(snn) => snn,
        Err(e) => {
            eprintln!("skipped: XLA runtime unavailable ({e})");
            return None;
        }
    };
    let w = codec::load_weights(dir.join("weights.bin")).unwrap();
    let net = BehavioralNet::new(snn.config().clone(), w.weights).unwrap();
    let ds = codec::load_dataset(dir.join("digits_test.bin")).unwrap();
    Some((snn, net, ds.images.into_iter().take(40).collect()))
}

#[test]
fn full_window_forward_matches_behavioral() {
    let Some((snn, net, images)) = load_stack() else { return };
    let refs: Vec<&Image> = images.iter().collect();
    let seeds: Vec<u32> = (0..refs.len() as u32).map(|i| 0xAB0 + i * 7).collect();
    let xla_counts = snn.spike_counts(&refs, &seeds).expect("xla forward");
    for ((img, &seed), counts) in refs.iter().zip(&seeds).zip(&xla_counts) {
        let beh = net.classify(img, seed);
        assert_eq!(
            counts, &beh.spike_counts,
            "XLA/behavioral divergence (seed {seed:#x}, label {})",
            img.label
        );
    }
}

#[test]
fn batch_splitting_consistent_across_sizes() {
    // 1, 8, 32 executables must all produce the same counts for the same
    // (image, seed) — padding and splitting must be invisible.
    let Some((snn, _, images)) = load_stack() else { return };
    let refs: Vec<&Image> = images.iter().take(3).collect();
    let seeds = vec![11u32, 22, 33];
    let one_by_one: Vec<Vec<u32>> = refs
        .iter()
        .zip(&seeds)
        .map(|(img, &s)| snn.spike_counts(&[img], &[s]).unwrap().remove(0))
        .collect();
    let batched = snn.spike_counts(&refs, &seeds).unwrap();
    assert_eq!(one_by_one, batched);
}

#[test]
fn chunked_path_composes_to_full_window() {
    let Some((snn, net, images)) = load_stack() else { return };
    let refs: Vec<&Image> = images.iter().take(snn.chunk_batch()).collect();
    let seeds: Vec<u32> = (0..refs.len() as u32).map(|i| 0xCAFE + i).collect();
    let mut st = snn.chunk_start(&refs, &seeds).unwrap();
    let window = snn.config().timesteps;
    let mut counts = Vec::new();
    while st.steps_run < window {
        counts = snn.chunk_advance(&mut st).unwrap();
    }
    assert_eq!(st.steps_run, window);
    for ((img, &seed), c) in refs.iter().zip(&seeds).zip(&counts) {
        let beh = net.classify(img, seed);
        assert_eq!(c, &beh.spike_counts, "chunked path diverges (seed {seed:#x})");
    }
}

#[test]
fn ann_executable_matches_rust_mlp() {
    let Some((snn, _, images)) = load_stack() else { return };
    let dir = artifacts_dir().unwrap();
    let mlp = Mlp::load(dir.join("ann_weights.bin")).unwrap();
    let refs: Vec<&Image> = images.iter().take(10).collect();
    let xla_logits = snn.ann_logits(&refs).unwrap();
    for (img, xl) in refs.iter().zip(&xla_logits) {
        let rl = mlp.logits(img);
        for (a, b) in xl.iter().zip(&rl) {
            assert!(
                (a - b).abs() < 1e-3,
                "ANN logits diverge: xla {a} vs rust {b} (label {})",
                img.label
            );
        }
    }
}

#[test]
fn trained_stack_is_accurate_over_xla() {
    let Some((snn, _, _)) = load_stack() else { return };
    let gen = DigitGen::new(2);
    let mut hits = 0;
    let n = 250u32;
    let images: Vec<Image> =
        (0..n).map(|i| gen.sample((i % 10) as u8, 100 + i / 10)).collect();
    let refs: Vec<&Image> = images.iter().collect();
    let seeds: Vec<u32> = (0..n).map(|i| 0xE0 + i * 13).collect();
    let counts = snn.spike_counts(&refs, &seeds).unwrap();
    for (img, c) in images.iter().zip(&counts) {
        let pred = c.iter().enumerate().max_by_key(|&(i, &v)| (v, usize::MAX - i)).unwrap().0;
        if pred as u8 == img.label {
            hits += 1;
        }
    }
    let acc = f64::from(hits) / f64::from(n);
    assert!(acc > 0.85, "XLA stack accuracy {acc} too low (calibrated plateau ≈ 0.99)");
}
