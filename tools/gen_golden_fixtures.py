#!/usr/bin/env python3
"""Golden-fixture generator: an independent transliteration of the
documented architectural semantics (encoder, LIF datapath, layered
schedule, pruning controller) used to derive the checked-in constants in
rust/tests/golden.rs.

Protocol (same as PRs 2-4): the transliteration must first reproduce the
existing pinned fixtures bit-for-bit -- all 9 single-layer cases, all 9
two-layer cases and all 6 heterogeneous 3-layer cases -- before any newly
generated constants are trusted. Run with no arguments; it validates the
sequential schedule, then cross-checks the BATCHED schedule
(`run_core_batch`, mirroring `RtlCore::run_fast_batch` after the
wide-lane layout change: multi-word transposed lane masks over
NEURON-MAJOR state planes, one weight-row walk per timestep serving
every image of the batch) against the same 24 fixture constants -- first
at the natural 3-image width, then through >64-lane chunks whose lanes
straddle the mask-word boundary -- then the SPARSE schedule (a CSR walk
mirroring `RtlCore::run_fast_sparse`, at keep-thresholds 0 and 1)
against the same constants, and finally prints the heterogeneous fixture
table.
"""

M32 = 0xFFFFFFFF

def splitmix32(x):
    z = (x + 0x9E3779B9) & M32
    z = ((z ^ (z >> 16)) * 0x85EBCA6B) & M32
    z = ((z ^ (z >> 13)) * 0xC2B2AE35) & M32
    return (z ^ (z >> 16)) & M32

def xorshift32_step(x):
    x ^= (x << 13) & M32
    x ^= x >> 17
    x ^= (x << 5) & M32
    return x & M32

def pixel_seed(seed, index):
    s = splitmix32((seed ^ (index * 0x9E3779B9 & M32)) & M32)
    return s if s != 0 else 0xDEADBEEF

IMG_PIXELS = 784

def fixture_image(kind):
    px = []
    for i in range(IMG_PIXELS):
        if kind == "ramp":
            px.append((i * 255) // 783)
        elif kind == "rev":
            px.append(255 - (i * 255) // 783)
        elif kind == "band":
            px.append(255 if 300 <= i < 500 else 30)
        else:
            raise ValueError(kind)
    return px

def fixture_weights_single():
    w = []
    for i in range(IMG_PIXELS):
        row = []
        for j in range(10):
            row.append(48 if i // 79 == j else ((i * 31 + j * 17) % 23) - 11)
        w.append(row)
    return [w]

def deep_fixture_stack():
    w0 = []
    for i in range(IMG_PIXELS):
        row = []
        for h in range(12):
            row.append(44 if i // 66 == h else ((i * 29 + h * 13) % 19) - 9)
        w0.append(row)
    w1 = []
    for h in range(12):
        row = []
        for j in range(10):
            row.append(100 if j == h % 10 else ((h * 11 + j * 5) % 15) - 7)
        w1.append(row)
    return [w0, w1]

def hetero_fixture_stack():
    """3 weight layers, [784, 14, 12, 10]."""
    w0 = []
    for i in range(IMG_PIXELS):
        row = []
        for h in range(14):
            row.append(42 if i // 56 == h else ((i * 23 + h * 7) % 17) - 8)
        w0.append(row)
    w1 = []
    for h in range(14):
        row = []
        for m in range(12):
            row.append(90 if m == h % 12 else ((h * 13 + m * 3) % 11) - 5)
        w1.append(row)
    w2 = []
    for m in range(12):
        row = []
        for j in range(10):
            row.append(70 if j == m % 10 else ((m * 7 + j * 11) % 13) - 6)
        w2.append(row)
    return [w0, w1, w2]

def sat(v, acc_bits):
    mx = (1 << (acc_bits - 1)) - 1
    return max(-mx, min(mx, v))

def leak(v, n):
    return v - (v >> n)   # python >> on negatives is arithmetic (floor)

class Layer:
    def __init__(self, n, v_th, decay, prune_after, acc_bits):
        self.n = n
        self.v_th = v_th
        self.decay = decay
        self.prune_after = prune_after  # 0 = off
        self.acc_bits = acc_bits
        self.acc = [0] * n
        self.count = [0] * n
        self.enabled = [True] * n
        self.step_fired = [False] * n  # OR-accumulated over the timestep

    def add_row(self, row):
        for j in range(self.n):
            if self.enabled[j]:
                self.acc[j] = sat(self.acc[j] + row[j], self.acc_bits)

    def add_row_sparse(self, entries):
        """CSR row: only the surviving (col, weight) pairs are visited."""
        for j, w in entries:
            if self.enabled[j]:
                self.acc[j] = sat(self.acc[j] + w, self.acc_bits)

    def leak_enabled(self):
        for j in range(self.n):
            if self.enabled[j]:
                self.acc[j] = leak(self.acc[j], self.decay)

    def latch_prune(self):
        if self.prune_after:
            for j in range(self.n):
                if self.count[j] >= self.prune_after:
                    self.enabled[j] = False

    def fire_check(self):
        fired = [False] * self.n
        for j in range(self.n):
            if self.enabled[j] and self.acc[j] >= self.v_th:
                fired[j] = True
                self.count[j] += 1
                self.acc[j] = 0
        for j in range(self.n):
            self.step_fired[j] |= fired[j]
        self.latch_prune()
        return fired

    def immediate_fire(self):
        any_f = False
        for j in range(self.n):
            if self.enabled[j] and self.acc[j] >= self.v_th:
                self.count[j] += 1
                self.acc[j] = 0
                self.step_fired[j] = True
                any_f = True
        if any_f:
            self.latch_prune()

def run_core(stack, image, seed, timesteps, fire_mode, leak_row_len,
             layer_params, acc_bits=24, csr=None):
    """fire_mode: 'end' | 'imm'; leak_row_len: None or row length (layer 0
    only); layer_params: list of (v_th, decay, prune_after) per layer;
    csr: None for the dense row walk, or a to_csr() stack -- the sparse
    sweep visits only the surviving (col, weight) pairs of active rows."""
    n_layers = len(stack)
    widths = [len(stack[l][0]) for l in range(n_layers)]
    layers = [Layer(widths[l], *layer_params[l], acc_bits) for l in range(n_layers)]
    states = [pixel_seed(seed, i) for i in range(IMG_PIXELS)]
    cycles = 0
    for _t in range(timesteps):
        for l in range(n_layers):
            n_in = IMG_PIXELS if l == 0 else widths[l - 1]
            # integrate walk, one input lane per clock (k = 1)
            for p in range(n_in):
                if l == 0:
                    states[p] = xorshift32_step(states[p])
                    spike = image[p] > (states[p] & 0xFF)
                else:
                    spike = layers[l - 1].step_fired[p]
                if spike:
                    if csr is None:
                        layers[l].add_row(stack[l][p])
                    else:
                        layers[l].add_row_sparse(csr[l][p])
                cycles += 1
                if fire_mode == "imm":
                    layers[l].immediate_fire()
                row_boundary = (l == 0 and leak_row_len is not None
                                and (p + 1) % leak_row_len == 0)
                if p + 1 == n_in or row_boundary:
                    layers[l].leak_enabled()
                    cycles += 1
            # fire clock
            if fire_mode == "end":
                layers[l].fire_check()
            else:
                layers[l].latch_prune()
            cycles += 1
        for l in range(n_layers):
            layers[l].step_fired = [False] * widths[l]
    counts = [layers[l].count for l in range(n_layers)]
    winner = max(range(widths[-1]), key=lambda j: (counts[-1][j], -j))
    return counts, winner, cycles

# --- validation against the pinned single-layer fixtures -------------------

SINGLE_CASES = [
    ("fire", "ramp", 0x11112222, [0, 0, 0, 1, 1, 1, 1, 1, 1, 1], 3, 6288),
    ("fire", "rev", 0x33334444, [1, 1, 1, 1, 1, 1, 1, 0, 0, 0], 0, 6288),
    ("fire", "band", 0x55556666, [0, 0, 0, 0, 1, 1, 1, 0, 0, 0], 4, 6288),
    ("leak", "ramp", 0x11112222, [0, 0, 0, 0, 6, 8, 8, 8, 8, 8], 5, 6504),
    ("leak", "rev", 0x33334444, [0, 0, 0, 4, 8, 8, 8, 7, 8, 0], 4, 6504),
    ("leak", "band", 0x55556666, [0, 0, 0, 0, 8, 8, 8, 1, 5, 8], 4, 6504),
    ("prune", "ramp", 0x11112222, [0, 2, 2, 2, 2, 2, 2, 2, 2, 2], 1, 6288),
    ("prune", "rev", 0x33334444, [2, 2, 2, 2, 2, 2, 2, 2, 2, 0], 0, 6288),
    ("prune", "band", 0x55556666, [2, 2, 2, 2, 2, 2, 2, 2, 2, 2], 0, 6288),
]

def single_cfg(name):
    # (v_th, decay, prune_after), fire_mode, row_len
    if name == "fire":
        return (6000, 3, 1), "imm", None
    if name == "leak":
        return (200, 3, 0), "end", 28
    if name == "prune":
        return (800, 3, 2), "end", None
    raise ValueError(name)

DEEP_CASES = [
    ("deep", "ramp", 0x11112222,
     [2, 6, 8, 8, 8, 8, 8, 8, 8, 8, 8, 8], [2, 3, 1, 2, 2, 1, 1, 1, 1, 1], 1, 6400),
    ("deep", "rev", 0x33334444,
     [8, 8, 8, 8, 8, 8, 8, 8, 8, 7, 6, 0], [3, 1, 1, 2, 1, 1, 2, 1, 1, 1], 0, 6400),
    ("deep", "band", 0x55556666,
     [5, 3, 6, 5, 8, 8, 8, 8, 4, 4, 6, 4], [2, 1, 1, 1, 1, 1, 1, 1, 0, 0], 0, 6400),
    ("deep_prune", "ramp", 0x11112222,
     [2] * 12, [1, 2, 0, 0, 0, 0, 0, 0, 0, 0], 1, 6400),
    ("deep_prune", "rev", 0x33334444,
     [2] * 11 + [1], [2, 1, 0, 0, 0, 0, 0, 0, 0, 0], 0, 6400),
    ("deep_prune", "band", 0x55556666,
     [2] * 12, [2, 1, 0, 0, 0, 0, 0, 0, 0, 0], 0, 6400),
    ("deep_fire", "ramp", 0x11112222,
     [2] * 12, [1, 1, 0, 0, 0, 0, 0, 0, 0, 0], 0, 6400),
    ("deep_fire", "rev", 0x33334444,
     [2] * 12, [1, 1, 0, 0, 0, 0, 0, 0, 0, 0], 0, 6400),
    ("deep_fire", "band", 0x55556666,
     [2] * 12, [1, 2, 0, 1, 0, 0, 0, 0, 0, 1], 1, 6400),
]

def deep_cfg(name):
    if name == "deep":
        return (300, 3, 0), "end"
    if name == "deep_prune":
        return (180, 3, 2), "end"
    if name == "deep_fire":
        return (150, 3, 2), "imm"
    raise ValueError(name)

# --- heterogeneous per-layer fixtures --------------------------------------

HETERO_PARAMS = [(260, 3, 2), (120, 2, 1), (40, 4, 0)]

# The pinned heterogeneous constants (rust/tests/golden.rs
# HETERO_GOLDEN_CASES): (config, image, seed, l0, l1, counts, winner,
# cycles).
HETERO_CASES = [
    ("hetero", "ramp", 0x11112222,
     [1] + [2] * 13, [1, 1, 0, 0, 0, 1, 0, 1, 0, 1, 0, 1],
     [1, 2, 0, 0, 0, 1, 0, 1, 0, 1], 1, 6528),
    ("hetero", "rev", 0x33334444,
     [2] * 13 + [1], [1, 0, 0, 1, 0, 1, 1, 1, 0, 1, 1, 0],
     [1, 0, 0, 1, 0, 1, 1, 1, 0, 1], 0, 6528),
    ("hetero", "band", 0x55556666,
     [2] * 14, [1, 1, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0],
     [1, 1, 0, 0, 0, 1, 0, 0, 0, 0], 0, 6528),
    ("hetero_fire", "ramp", 0x11112222,
     [1] + [2] * 13, [0, 1, 1, 0, 0, 0, 0, 0, 0, 0, 0, 0],
     [0, 1, 1, 0, 0, 0, 0, 0, 0, 0], 1, 6528),
    ("hetero_fire", "rev", 0x33334444,
     [2] * 13 + [1], [1] + [0] * 11,
     [1] + [0] * 9, 0, 6528),
    ("hetero_fire", "band", 0x55556666,
     [2] * 14, [1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 1, 1],
     [2, 2, 1, 1, 1, 0, 0, 0, 0, 0], 0, 6528),
]

def hetero_mode(cfg):
    return "end" if cfg == "hetero" else "imm"

def validate():
    stack = fixture_weights_single()
    for cfg, img, seed, counts, winner, cycles in SINGLE_CASES:
        params, mode, row = single_cfg(cfg)
        got_c, got_w, got_cy = run_core(
            stack, fixture_image(img), seed, 8, mode, row, [params])
        assert got_c[-1] == counts, (cfg, img, got_c[-1], counts)
        assert got_w == winner and got_cy == cycles, (cfg, img, got_w, got_cy)
    dstack = deep_fixture_stack()
    for cfg, img, seed, hidden, counts, winner, cycles in DEEP_CASES:
        params, mode = deep_cfg(cfg)
        got_c, got_w, got_cy = run_core(
            dstack, fixture_image(img), seed, 8, mode, None, [params, params])
        assert got_c[0] == hidden, (cfg, img, got_c[0], hidden)
        assert got_c[1] == counts, (cfg, img, got_c[1], counts)
        assert got_w == winner and got_cy == cycles, (cfg, img, got_w, got_cy)
    hstack = hetero_fixture_stack()
    for cfg, img, seed, l0, l1, counts, winner, cycles in HETERO_CASES:
        got_c, got_w, got_cy = run_core(
            hstack, fixture_image(img), seed, 8, hetero_mode(cfg), None,
            HETERO_PARAMS)
        assert got_c[0] == l0 and got_c[1] == l1, (cfg, img, got_c)
        assert got_c[2] == counts, (cfg, img, got_c[2], counts)
        assert got_w == winner and got_cy == cycles, (cfg, img, got_w, got_cy)
    print("validated: all 24 pinned fixtures reproduced bit-for-bit")

# --- batched-schedule cross-check ------------------------------------------

def full_mask_words(lanes):
    """Multi-word all-lanes mask: lane b at word b // 64, bit b % 64."""
    lw = max((lanes + 63) // 64, 1)
    return [((1 << min(64, lanes - wb * 64)) - 1 if lanes > wb * 64 else 0)
            for wb in range(lw)]

class BatchLayer:
    """One layer x all batch lanes, mirroring the Rust LifBatchArray:
    NEURON-MAJOR state planes (plane[j * lanes + b], so the wide row
    apply is a contiguous sweep across lanes) and multi-word per-neuron
    lane-enable masks (enabled[j * lw + wb] bit b % 64). Per-lane
    dynamics are identical to the sequential Layer -- lanes share
    nothing, so cross-lane reordering commutes."""

    def __init__(self, n, v_th, decay, prune_after, acc_bits, lanes):
        self.n = n
        self.v_th = v_th
        self.decay = decay
        self.prune_after = prune_after
        self.acc_bits = acc_bits
        self.lanes = lanes
        self.lw = max((lanes + 63) // 64, 1)
        self.acc = [0] * (n * lanes)
        self.count = [0] * (n * lanes)
        self.enabled = full_mask_words(lanes) * n
        # Multi-word transposed fire masks, OR-accumulated per timestep:
        # step_fired[j * lw + wb] bit b % 64.
        self.step_fired = [0] * (n * self.lw)

    def enabled_at(self, b, j):
        return (self.enabled[j * self.lw + b // 64] >> (b % 64)) & 1

    def add_row_lanes(self, lane_mask, row, j0=0, j1=None):
        """ONE row fetch applied to every masked-and-enabled lane: the
        neuron-major wide sweep (Rust add_row_lanes). The optional
        [j0, j1) bound restricts the sweep to one neuron range -- a
        shard's private plane slice in the thread-parallel kernel."""
        for j in range(j0, self.n if j1 is None else j1):
            base = j * self.lanes
            w = row[j]
            for wb in range(self.lw):
                m = lane_mask[wb] & self.enabled[j * self.lw + wb]
                while m:
                    b = wb * 64 + ((m & -m).bit_length() - 1)
                    m &= m - 1
                    self.acc[base + b] = sat(self.acc[base + b] + w,
                                             self.acc_bits)

    def leak_enabled(self, b, j0=0, j1=None):
        for j in range(j0, self.n if j1 is None else j1):
            if self.enabled_at(b, j):
                idx = j * self.lanes + b
                self.acc[idx] = leak(self.acc[idx], self.decay)

    def latch_prune(self, b, j0=0, j1=None):
        if self.prune_after:
            wb, bit = b // 64, b % 64
            for j in range(j0, self.n if j1 is None else j1):
                if self.count[j * self.lanes + b] >= self.prune_after:
                    self.enabled[j * self.lw + wb] &= ~(1 << bit)

    def fire_check(self, b, j0=0, j1=None):
        wb, bit = b // 64, b % 64
        for j in range(j0, self.n if j1 is None else j1):
            idx = j * self.lanes + b
            if self.enabled_at(b, j) and self.acc[idx] >= self.v_th:
                self.step_fired[j * self.lw + wb] |= 1 << bit
                self.count[idx] += 1
                self.acc[idx] = 0
        self.latch_prune(b, j0, j1)

    def immediate_fire(self, b):
        wb, bit = b // 64, b % 64
        any_f = False
        for j in range(self.n):
            idx = j * self.lanes + b
            if self.enabled_at(b, j) and self.acc[idx] >= self.v_th:
                self.count[idx] += 1
                self.acc[idx] = 0
                self.step_fired[j * self.lw + wb] |= 1 << bit
                any_f = True
        if any_f:
            self.latch_prune(b)

def split_ranges(n, parts):
    """Contiguous near-even partition of [0, n) into min(parts, n)
    nonempty ranges -- mirroring the Rust kernel's neuron-range tiling
    (base + remainder spread over the leading ranges)."""
    parts = max(min(parts, n), 1)
    base, rem = divmod(n, parts)
    ranges, j0 = [], 0
    for w in range(parts):
        j1 = j0 + base + (1 if w < rem else 0)
        ranges.append((j0, j1))
        j0 = j1
    return ranges

def run_core_batch(stack, images, seeds, timesteps, fire_mode, leak_row_len,
                   layer_params, acc_bits=24, shards=None):
    """The batched sweep, mirroring RtlCore::run_fast_batch after the
    wide-lane layout change: per timestep, per layer, per input, build the
    MULTI-WORD transposed lane mask (any batch width, not just 64), then
    walk the weight row once and apply it across all firing lanes of the
    NEURON-MAJOR planes in one sweep. Per-lane state (PRNG streams,
    accumulator/count/enable plane slices, cycle counters) is disjoint,
    so the lane-order swap inside add_row_lanes only reorders independent
    work -- the commutation argument behind the Rust engine's
    bit-exactness.

    With `shards` set, end-of-step layer sweeps run the THREAD-PARALLEL
    schedule instead (RtlCore::with_batch_threads): the layer's neuron
    range splits into `shards` contiguous ranges, the input masks are
    fixed up front (layer-0 draws happen once; under end-of-step firing
    the relay masks and enables cannot change mid-sweep), and each range
    performs its own complete integrate/leak/fire walk over its private
    plane slice. Ranges are processed in REVERSED order to prove the
    commutation claim: per-(neuron, lane) cell the event sequence is
    untouched, so any range order -- including true concurrency -- is
    bit-identical. Immediate-fire layers keep the serial sweep, exactly
    like the Rust kernel (mid-walk fires re-gate the layer)."""
    n_layers = len(stack)
    widths = [len(stack[l][0]) for l in range(n_layers)]
    B = len(images)
    lw = max((B + 63) // 64, 1)
    layers = [BatchLayer(widths[l], *layer_params[l], acc_bits, B)
              for l in range(n_layers)]
    states = [[pixel_seed(seeds[b], i) for i in range(IMG_PIXELS)]
              for b in range(B)]
    cycles = [0] * B
    batch = list(range(B))
    for _t in range(timesteps):
        for l in range(n_layers):
            n_in = IMG_PIXELS if l == 0 else widths[l - 1]
            prev = layers[l - 1] if l > 0 else None

            def mask_for(p):
                # transposed multi-word active mask for input p
                if l != 0:
                    return prev.step_fired[p * lw:(p + 1) * lw]
                mask = [0] * lw
                for b in batch:
                    states[b][p] = xorshift32_step(states[b][p])
                    if images[b][p] > (states[b][p] & 0xFF):
                        mask[b // 64] |= 1 << (b % 64)
                return mask

            def boundary(p):
                row_boundary = (l == 0 and leak_row_len is not None
                                and (p + 1) % leak_row_len == 0)
                return p + 1 == n_in or row_boundary

            if shards and fire_mode == "end":
                # Sharded schedule: masks fixed up front, then each
                # neuron range walks the whole layer independently.
                masks = [mask_for(p) for p in range(n_in)]
                for j0, j1 in reversed(split_ranges(widths[l], shards)):
                    for p in range(n_in):
                        layers[l].add_row_lanes(masks[p], stack[l][p], j0, j1)
                        if boundary(p):
                            for b in batch:
                                layers[l].leak_enabled(b, j0, j1)
                    for b in batch:
                        layers[l].fire_check(b, j0, j1)
                # Cycle tally is whole-row work, counted once per layer
                # (the Rust kernel's rank-0 rule), not once per range.
                for p in range(n_in):
                    for b in batch:
                        cycles[b] += 1
                    if boundary(p):
                        for b in batch:
                            cycles[b] += 1
                for b in batch:
                    cycles[b] += 1
                continue

            for p in range(n_in):
                mask = mask_for(p)
                # ONE row walk serves every firing lane of the batch
                layers[l].add_row_lanes(mask, stack[l][p])
                for b in batch:
                    cycles[b] += 1
                    if fire_mode == "imm":
                        layers[l].immediate_fire(b)
                if boundary(p):
                    for b in batch:
                        layers[l].leak_enabled(b)
                        cycles[b] += 1
            for b in batch:
                if fire_mode == "end":
                    layers[l].fire_check(b)
                else:
                    layers[l].latch_prune(b)
                cycles[b] += 1
        for l in range(n_layers):
            layers[l].step_fired = [0] * (widths[l] * lw)
    out = []
    for b in range(B):
        counts = [[layers[l].count[j * B + b] for j in range(widths[l])]
                  for l in range(n_layers)]
        winner = max(range(widths[-1]), key=lambda j: (counts[-1][j], -j))
        out.append((counts, winner, cycles[b]))
    return out

def validate_batch():
    """Anchor the batched schedule: all 24 pinned fixture rows reproduced
    by run_core_batch, batching each config's three images into ONE
    sweep."""
    stack = fixture_weights_single()
    for cfg_name in ["fire", "leak", "prune"]:
        cases = [c for c in SINGLE_CASES if c[0] == cfg_name]
        params, mode, row = single_cfg(cfg_name)
        got = run_core_batch(stack, [fixture_image(c[1]) for c in cases],
                             [c[2] for c in cases], 8, mode, row, [params])
        for (cfg, img, _s, counts, winner, cycles), (gc, gw, gcy) in zip(cases, got):
            assert gc[-1] == counts and gw == winner and gcy == cycles, \
                ("batched", cfg, img, gc[-1], gw, gcy)
    dstack = deep_fixture_stack()
    for cfg_name in ["deep", "deep_prune", "deep_fire"]:
        cases = [c for c in DEEP_CASES if c[0] == cfg_name]
        params, mode = deep_cfg(cfg_name)
        got = run_core_batch(dstack, [fixture_image(c[1]) for c in cases],
                             [c[2] for c in cases], 8, mode, None,
                             [params, params])
        for (cfg, img, _s, hidden, counts, winner, cycles), (gc, gw, gcy) in zip(cases, got):
            assert gc[0] == hidden and gc[1] == counts, ("batched", cfg, img, gc)
            assert gw == winner and gcy == cycles, ("batched", cfg, img, gw, gcy)
    hstack = hetero_fixture_stack()
    for cfg_name in ["hetero", "hetero_fire"]:
        cases = [c for c in HETERO_CASES if c[0] == cfg_name]
        got = run_core_batch(hstack, [fixture_image(c[1]) for c in cases],
                             [c[2] for c in cases], 8, hetero_mode(cfg_name),
                             None, HETERO_PARAMS)
        for (cfg, img, _s, l0, l1, counts, winner, cycles), (gc, gw, gcy) in zip(cases, got):
            assert gc[0] == l0 and gc[1] == l1 and gc[2] == counts, \
                ("batched", cfg, img, gc)
            assert gw == winner and gcy == cycles, ("batched", cfg, img, gw, gcy)
    print("validated: batched sweep reproduces all 24 fixtures image-for-image")

def validate_batch_sharded():
    """Anchor the thread-parallel schedule: all 24 pinned fixture rows
    reproduced through a 3-range neuron split whose ranges run in
    REVERSED order (split_ranges leaves odd remainders on the leading
    ranges, so 10 -> 4+3+3, 12 -> 4+4+4, 14 -> 5+5+4 all get exercised).
    End-of-step configs take the sharded sweep; immediate-fire configs
    keep the serial sweep, mirroring the Rust kernel's dispatch."""
    shards = 3
    stack = fixture_weights_single()
    for cfg_name in ["fire", "leak", "prune"]:
        cases = [c for c in SINGLE_CASES if c[0] == cfg_name]
        params, mode, row = single_cfg(cfg_name)
        got = run_core_batch(stack, [fixture_image(c[1]) for c in cases],
                             [c[2] for c in cases], 8, mode, row, [params],
                             shards=shards)
        for (cfg, img, _s, counts, winner, cycles), (gc, gw, gcy) in zip(cases, got):
            assert gc[-1] == counts and gw == winner and gcy == cycles, \
                ("sharded", cfg, img, gc[-1], gw, gcy)
    dstack = deep_fixture_stack()
    for cfg_name in ["deep", "deep_prune", "deep_fire"]:
        cases = [c for c in DEEP_CASES if c[0] == cfg_name]
        params, mode = deep_cfg(cfg_name)
        got = run_core_batch(dstack, [fixture_image(c[1]) for c in cases],
                             [c[2] for c in cases], 8, mode, None,
                             [params, params], shards=shards)
        for (cfg, img, _s, hidden, counts, winner, cycles), (gc, gw, gcy) in zip(cases, got):
            assert gc[0] == hidden and gc[1] == counts, ("sharded", cfg, img, gc)
            assert gw == winner and gcy == cycles, ("sharded", cfg, img, gw, gcy)
    hstack = hetero_fixture_stack()
    for cfg_name in ["hetero", "hetero_fire"]:
        cases = [c for c in HETERO_CASES if c[0] == cfg_name]
        got = run_core_batch(hstack, [fixture_image(c[1]) for c in cases],
                             [c[2] for c in cases], 8, hetero_mode(cfg_name),
                             None, HETERO_PARAMS, shards=shards)
        for (cfg, img, _s, l0, l1, counts, winner, cycles), (gc, gw, gcy) in zip(cases, got):
            assert gc[0] == l0 and gc[1] == l1 and gc[2] == counts, \
                ("sharded", cfg, img, gc)
            assert gw == winner and gcy == cycles, ("sharded", cfg, img, gw, gcy)
    print("validated: 3-range sharded sweep (reversed range order) "
          "reproduces all 24 fixtures bit-for-bit")

WIDE_LANES = 66  # crosses the 64-lane mask-word boundary: words 0 and 1

def validate_batch_wide():
    """Anchor the wide-lane layout: every one of the 24 pinned fixture
    rows reproduced through a single >64-lane chunk (66 lanes = the
    family's three images replicated 22x, so lanes 63/64/65 straddle the
    mask-word boundary). Each lane must still match its pinned
    constants bit-for-bit."""
    def check(cases, got, expect_of):
        reps = WIDE_LANES // len(cases)
        assert len(got) == len(cases) * reps
        for lane, (gc, gw, gcy) in enumerate(got):
            case = cases[lane % len(cases)]
            counts, winner, cycles = expect_of(case)
            for l, want in enumerate(counts):
                if want is not None:
                    assert gc[l] == want, ("wide", case[0], case[1], lane, l,
                                           gc[l], want)
            assert gw == winner and gcy == cycles, \
                ("wide", case[0], case[1], lane, gw, gcy)

    def widen(cases):
        reps = WIDE_LANES // len(cases)
        images = [fixture_image(c[1]) for c in cases] * reps
        seeds = [c[2] for c in cases] * reps
        return images, seeds

    stack = fixture_weights_single()
    for cfg_name in ["fire", "leak", "prune"]:
        cases = [c for c in SINGLE_CASES if c[0] == cfg_name]
        params, mode, row = single_cfg(cfg_name)
        images, seeds = widen(cases)
        got = run_core_batch(stack, images, seeds, 8, mode, row, [params])
        check(cases, got, lambda c: ([c[3]], c[4], c[5]))
    dstack = deep_fixture_stack()
    for cfg_name in ["deep", "deep_prune", "deep_fire"]:
        cases = [c for c in DEEP_CASES if c[0] == cfg_name]
        params, mode = deep_cfg(cfg_name)
        images, seeds = widen(cases)
        got = run_core_batch(dstack, images, seeds, 8, mode, None,
                             [params, params])
        check(cases, got, lambda c: ([c[3], c[4]], c[5], c[6]))
    hstack = hetero_fixture_stack()
    for cfg_name in ["hetero", "hetero_fire"]:
        cases = [c for c in HETERO_CASES if c[0] == cfg_name]
        images, seeds = widen(cases)
        got = run_core_batch(hstack, images, seeds, 8, hetero_mode(cfg_name),
                             None, HETERO_PARAMS)
        check(cases, got, lambda c: ([c[3], c[4], c[5]], c[6], c[7]))
    print(f"validated: all 24 fixtures reproduced through {WIDE_LANES}-lane "
          "multi-word chunks (lanes straddle the 64-bit mask-word boundary)")

# --- sparse (CSR) sweep cross-check ----------------------------------------

def to_csr(stack, threshold):
    """Per layer, per input row: the (col, weight) pairs with |w| >=
    threshold, in column order -- mirroring fixed::SparseWeightStack's keep
    predicate. Threshold 0 keeps every entry (explicit zeros included);
    threshold 1 drops exactly the explicit zeros, whose adds are
    state-neutral, so both must reproduce the dense fixtures bit-for-bit."""
    assert threshold >= 0
    return [[[(j, w) for j, w in enumerate(row) if abs(w) >= threshold]
             for row in layer] for layer in stack]

def validate_sparse():
    """Anchor the event-driven sparse sweep: all 24 pinned fixture rows
    reproduced through the CSR walk, at threshold 0 (every entry kept) AND
    at threshold 1 (explicit zeros dropped -- the smallest real pruning)."""
    for threshold in (0, 1):
        stack = fixture_weights_single()
        scsr = to_csr(stack, threshold)
        for cfg, img, seed, counts, winner, cycles in SINGLE_CASES:
            params, mode, row = single_cfg(cfg)
            got_c, got_w, got_cy = run_core(
                stack, fixture_image(img), seed, 8, mode, row, [params],
                csr=scsr)
            assert got_c[-1] == counts and got_w == winner and got_cy == cycles, \
                ("sparse", threshold, cfg, img, got_c[-1], got_w, got_cy)
        dstack = deep_fixture_stack()
        dcsr = to_csr(dstack, threshold)
        for cfg, img, seed, hidden, counts, winner, cycles in DEEP_CASES:
            params, mode = deep_cfg(cfg)
            got_c, got_w, got_cy = run_core(
                dstack, fixture_image(img), seed, 8, mode, None,
                [params, params], csr=dcsr)
            assert got_c[0] == hidden and got_c[1] == counts, \
                ("sparse", threshold, cfg, img, got_c)
            assert got_w == winner and got_cy == cycles, \
                ("sparse", threshold, cfg, img, got_w, got_cy)
        hstack = hetero_fixture_stack()
        hcsr = to_csr(hstack, threshold)
        for cfg, img, seed, l0, l1, counts, winner, cycles in HETERO_CASES:
            got_c, got_w, got_cy = run_core(
                hstack, fixture_image(img), seed, 8, hetero_mode(cfg), None,
                HETERO_PARAMS, csr=hcsr)
            assert got_c[0] == l0 and got_c[1] == l1 and got_c[2] == counts, \
                ("sparse", threshold, cfg, img, got_c)
            assert got_w == winner and got_cy == cycles, \
                ("sparse", threshold, cfg, img, got_w, got_cy)
    print("validated: sparse CSR sweep reproduces all 24 fixtures "
          "at thresholds 0 and 1")

def hetero():
    stack = hetero_fixture_stack()
    for mode_name, mode in [("hetero", "end"), ("hetero_fire", "imm")]:
        for img in ["ramp", "rev", "band"]:
            seed = {"ramp": 0x11112222, "rev": 0x33334444, "band": 0x55556666}[img]
            counts, winner, cycles = run_core(
                stack, fixture_image(img), seed, 8, mode, None, HETERO_PARAMS)
            print(f"{mode_name}/{img}: l0={counts[0]} l1={counts[1]} "
                  f"l2={counts[2]} winner={winner} cycles={cycles}")

if __name__ == "__main__":
    validate()
    validate_batch()
    validate_batch_sharded()
    validate_batch_wide()
    validate_sparse()
    hetero()
