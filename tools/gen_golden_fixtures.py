#!/usr/bin/env python3
"""Golden-fixture generator: an independent transliteration of the
documented architectural semantics (encoder, LIF datapath, layered
schedule, pruning controller) used to derive the checked-in constants in
rust/tests/golden.rs.

Protocol (same as PRs 2-3): the transliteration must first reproduce the
existing pinned fixtures bit-for-bit -- all 9 single-layer cases and all
9 two-layer cases -- before any newly generated constants are trusted.
Run with no arguments; it validates, then prints the heterogeneous
per-layer fixture table.
"""

M32 = 0xFFFFFFFF

def splitmix32(x):
    z = (x + 0x9E3779B9) & M32
    z = ((z ^ (z >> 16)) * 0x85EBCA6B) & M32
    z = ((z ^ (z >> 13)) * 0xC2B2AE35) & M32
    return (z ^ (z >> 16)) & M32

def xorshift32_step(x):
    x ^= (x << 13) & M32
    x ^= x >> 17
    x ^= (x << 5) & M32
    return x & M32

def pixel_seed(seed, index):
    s = splitmix32((seed ^ (index * 0x9E3779B9 & M32)) & M32)
    return s if s != 0 else 0xDEADBEEF

IMG_PIXELS = 784

def fixture_image(kind):
    px = []
    for i in range(IMG_PIXELS):
        if kind == "ramp":
            px.append((i * 255) // 783)
        elif kind == "rev":
            px.append(255 - (i * 255) // 783)
        elif kind == "band":
            px.append(255 if 300 <= i < 500 else 30)
        else:
            raise ValueError(kind)
    return px

def fixture_weights_single():
    w = []
    for i in range(IMG_PIXELS):
        row = []
        for j in range(10):
            row.append(48 if i // 79 == j else ((i * 31 + j * 17) % 23) - 11)
        w.append(row)
    return [w]

def deep_fixture_stack():
    w0 = []
    for i in range(IMG_PIXELS):
        row = []
        for h in range(12):
            row.append(44 if i // 66 == h else ((i * 29 + h * 13) % 19) - 9)
        w0.append(row)
    w1 = []
    for h in range(12):
        row = []
        for j in range(10):
            row.append(100 if j == h % 10 else ((h * 11 + j * 5) % 15) - 7)
        w1.append(row)
    return [w0, w1]

def hetero_fixture_stack():
    """3 weight layers, [784, 14, 12, 10]."""
    w0 = []
    for i in range(IMG_PIXELS):
        row = []
        for h in range(14):
            row.append(42 if i // 56 == h else ((i * 23 + h * 7) % 17) - 8)
        w0.append(row)
    w1 = []
    for h in range(14):
        row = []
        for m in range(12):
            row.append(90 if m == h % 12 else ((h * 13 + m * 3) % 11) - 5)
        w1.append(row)
    w2 = []
    for m in range(12):
        row = []
        for j in range(10):
            row.append(70 if j == m % 10 else ((m * 7 + j * 11) % 13) - 6)
        w2.append(row)
    return [w0, w1, w2]

def sat(v, acc_bits):
    mx = (1 << (acc_bits - 1)) - 1
    return max(-mx, min(mx, v))

def leak(v, n):
    return v - (v >> n)   # python >> on negatives is arithmetic (floor)

class Layer:
    def __init__(self, n, v_th, decay, prune_after, acc_bits):
        self.n = n
        self.v_th = v_th
        self.decay = decay
        self.prune_after = prune_after  # 0 = off
        self.acc_bits = acc_bits
        self.acc = [0] * n
        self.count = [0] * n
        self.enabled = [True] * n
        self.step_fired = [False] * n  # OR-accumulated over the timestep

    def add_row(self, row):
        for j in range(self.n):
            if self.enabled[j]:
                self.acc[j] = sat(self.acc[j] + row[j], self.acc_bits)

    def leak_enabled(self):
        for j in range(self.n):
            if self.enabled[j]:
                self.acc[j] = leak(self.acc[j], self.decay)

    def latch_prune(self):
        if self.prune_after:
            for j in range(self.n):
                if self.count[j] >= self.prune_after:
                    self.enabled[j] = False

    def fire_check(self):
        fired = [False] * self.n
        for j in range(self.n):
            if self.enabled[j] and self.acc[j] >= self.v_th:
                fired[j] = True
                self.count[j] += 1
                self.acc[j] = 0
        for j in range(self.n):
            self.step_fired[j] |= fired[j]
        self.latch_prune()
        return fired

    def immediate_fire(self):
        any_f = False
        for j in range(self.n):
            if self.enabled[j] and self.acc[j] >= self.v_th:
                self.count[j] += 1
                self.acc[j] = 0
                self.step_fired[j] = True
                any_f = True
        if any_f:
            self.latch_prune()

def run_core(stack, image, seed, timesteps, fire_mode, leak_row_len,
             layer_params, acc_bits=24):
    """fire_mode: 'end' | 'imm'; leak_row_len: None or row length (layer 0
    only); layer_params: list of (v_th, decay, prune_after) per layer."""
    n_layers = len(stack)
    widths = [len(stack[l][0]) for l in range(n_layers)]
    layers = [Layer(widths[l], *layer_params[l], acc_bits) for l in range(n_layers)]
    states = [pixel_seed(seed, i) for i in range(IMG_PIXELS)]
    cycles = 0
    for _t in range(timesteps):
        for l in range(n_layers):
            n_in = IMG_PIXELS if l == 0 else widths[l - 1]
            # integrate walk, one input lane per clock (k = 1)
            for p in range(n_in):
                if l == 0:
                    states[p] = xorshift32_step(states[p])
                    spike = image[p] > (states[p] & 0xFF)
                else:
                    spike = layers[l - 1].step_fired[p]
                if spike:
                    layers[l].add_row(stack[l][p])
                cycles += 1
                if fire_mode == "imm":
                    layers[l].immediate_fire()
                row_boundary = (l == 0 and leak_row_len is not None
                                and (p + 1) % leak_row_len == 0)
                if p + 1 == n_in or row_boundary:
                    layers[l].leak_enabled()
                    cycles += 1
            # fire clock
            if fire_mode == "end":
                layers[l].fire_check()
            else:
                layers[l].latch_prune()
            cycles += 1
        for l in range(n_layers):
            layers[l].step_fired = [False] * widths[l]
    counts = [layers[l].count for l in range(n_layers)]
    winner = max(range(widths[-1]), key=lambda j: (counts[-1][j], -j))
    return counts, winner, cycles

# --- validation against the pinned single-layer fixtures -------------------

SINGLE_CASES = [
    ("fire", "ramp", 0x11112222, [0, 0, 0, 1, 1, 1, 1, 1, 1, 1], 3, 6288),
    ("fire", "rev", 0x33334444, [1, 1, 1, 1, 1, 1, 1, 0, 0, 0], 0, 6288),
    ("fire", "band", 0x55556666, [0, 0, 0, 0, 1, 1, 1, 0, 0, 0], 4, 6288),
    ("leak", "ramp", 0x11112222, [0, 0, 0, 0, 6, 8, 8, 8, 8, 8], 5, 6504),
    ("leak", "rev", 0x33334444, [0, 0, 0, 4, 8, 8, 8, 7, 8, 0], 4, 6504),
    ("leak", "band", 0x55556666, [0, 0, 0, 0, 8, 8, 8, 1, 5, 8], 4, 6504),
    ("prune", "ramp", 0x11112222, [0, 2, 2, 2, 2, 2, 2, 2, 2, 2], 1, 6288),
    ("prune", "rev", 0x33334444, [2, 2, 2, 2, 2, 2, 2, 2, 2, 0], 0, 6288),
    ("prune", "band", 0x55556666, [2, 2, 2, 2, 2, 2, 2, 2, 2, 2], 0, 6288),
]

def single_cfg(name):
    # (v_th, decay, prune_after), fire_mode, row_len
    if name == "fire":
        return (6000, 3, 1), "imm", None
    if name == "leak":
        return (200, 3, 0), "end", 28
    if name == "prune":
        return (800, 3, 2), "end", None
    raise ValueError(name)

DEEP_CASES = [
    ("deep", "ramp", 0x11112222,
     [2, 6, 8, 8, 8, 8, 8, 8, 8, 8, 8, 8], [2, 3, 1, 2, 2, 1, 1, 1, 1, 1], 1, 6400),
    ("deep", "rev", 0x33334444,
     [8, 8, 8, 8, 8, 8, 8, 8, 8, 7, 6, 0], [3, 1, 1, 2, 1, 1, 2, 1, 1, 1], 0, 6400),
    ("deep", "band", 0x55556666,
     [5, 3, 6, 5, 8, 8, 8, 8, 4, 4, 6, 4], [2, 1, 1, 1, 1, 1, 1, 1, 0, 0], 0, 6400),
    ("deep_prune", "ramp", 0x11112222,
     [2] * 12, [1, 2, 0, 0, 0, 0, 0, 0, 0, 0], 1, 6400),
    ("deep_prune", "rev", 0x33334444,
     [2] * 11 + [1], [2, 1, 0, 0, 0, 0, 0, 0, 0, 0], 0, 6400),
    ("deep_prune", "band", 0x55556666,
     [2] * 12, [2, 1, 0, 0, 0, 0, 0, 0, 0, 0], 0, 6400),
    ("deep_fire", "ramp", 0x11112222,
     [2] * 12, [1, 1, 0, 0, 0, 0, 0, 0, 0, 0], 0, 6400),
    ("deep_fire", "rev", 0x33334444,
     [2] * 12, [1, 1, 0, 0, 0, 0, 0, 0, 0, 0], 0, 6400),
    ("deep_fire", "band", 0x55556666,
     [2] * 12, [1, 2, 0, 1, 0, 0, 0, 0, 0, 1], 1, 6400),
]

def deep_cfg(name):
    if name == "deep":
        return (300, 3, 0), "end"
    if name == "deep_prune":
        return (180, 3, 2), "end"
    if name == "deep_fire":
        return (150, 3, 2), "imm"
    raise ValueError(name)

def validate():
    stack = fixture_weights_single()
    for cfg, img, seed, counts, winner, cycles in SINGLE_CASES:
        params, mode, row = single_cfg(cfg)
        got_c, got_w, got_cy = run_core(
            stack, fixture_image(img), seed, 8, mode, row, [params])
        assert got_c[-1] == counts, (cfg, img, got_c[-1], counts)
        assert got_w == winner and got_cy == cycles, (cfg, img, got_w, got_cy)
    dstack = deep_fixture_stack()
    for cfg, img, seed, hidden, counts, winner, cycles in DEEP_CASES:
        params, mode = deep_cfg(cfg)
        got_c, got_w, got_cy = run_core(
            dstack, fixture_image(img), seed, 8, mode, None, [params, params])
        assert got_c[0] == hidden, (cfg, img, got_c[0], hidden)
        assert got_c[1] == counts, (cfg, img, got_c[1], counts)
        assert got_w == winner and got_cy == cycles, (cfg, img, got_w, got_cy)
    print("validated: all 18 pinned fixtures reproduced bit-for-bit")

# --- heterogeneous per-layer fixtures --------------------------------------

HETERO_PARAMS = [(260, 3, 2), (120, 2, 1), (40, 4, 0)]

def hetero():
    stack = hetero_fixture_stack()
    for mode_name, mode in [("hetero", "end"), ("hetero_fire", "imm")]:
        for img in ["ramp", "rev", "band"]:
            seed = {"ramp": 0x11112222, "rev": 0x33334444, "band": 0x55556666}[img]
            counts, winner, cycles = run_core(
                stack, fixture_image(img), seed, 8, mode, None, HETERO_PARAMS)
            print(f"{mode_name}/{img}: l0={counts[0]} l1={counts[1]} "
                  f"l2={counts[2]} winner={winner} cycles={cycles}")

if __name__ == "__main__":
    validate()
    hetero()
