#!/usr/bin/env bash
# Perf-trajectory bench runner: builds the release binary and emits
# BENCH_10.json (images/sec for the RTL cycle path vs fast path, batched
# vs per-image engine throughput at batch 1/8/32/64/128/256 — the wide
# rows run one multi-word chunk — sparse-vs-dense engine throughput and
# adds-performed at 100/50/10% weight density for [784,10] and
# [784,128,10] plus the 128-lane sparse_batched_wide row, the
# parallel_kernel rows (dense images/s at threads 1/2/4 x hidden
# 128/512 x lanes 64/128/256, the sharded 10%-density CSR sweep, and
# the autotuned-vs-fixed-256 lane plan at batch 256),
# 1/2/3-layer depth rows with the shared- vs
# per-layer-v_th calibration accuracy, coordinator qps + p50/p99 at
# 1/2/4/8 workers over the batched backends, large-batch latency with
# intra-batch fan-out off vs on, the calibrated fan-out crossover, an
# open-loop paced-arrival tail-latency row free of coordinated omission,
# and a fault-injection row measuring goodput and recovery counters
# under a deterministic mixed fault plan, and the pallas_lint row timing
# the full-tree static-analysis pass). Pass --quick for a short run.
#
#   tools/run_bench.sh [--quick]
set -euo pipefail
cd "$(dirname "$0")/.."
cargo run --release --bin bench-report -- "$@"
echo "wrote $(pwd)/BENCH_10.json"
