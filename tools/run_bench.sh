#!/usr/bin/env bash
# Perf-trajectory bench runner: builds the release binary and emits
# BENCH_4.json (images/sec for the RTL cycle path vs fast path, 1/2/3-layer
# depth rows with the shared- vs per-layer-v_th calibration accuracy,
# coordinator qps + p50/p99 at 1/2/4/8 workers on the sharded
# work-stealing ingress, and large-batch latency with intra-batch fan-out
# off vs on). Pass --quick for a short run.
#
#   tools/run_bench.sh [--quick]
set -euo pipefail
cd "$(dirname "$0")/.."
cargo run --release --bin bench-report -- "$@"
echo "wrote $(pwd)/BENCH_4.json"
