#!/usr/bin/env bash
# Perf-trajectory bench runner: builds the release binary and emits
# BENCH_1.json (images/sec for the RTL cycle path vs fast path, plus
# coordinator throughput at 1/2/4 workers). Pass --quick for a short run.
#
#   tools/run_bench.sh [--quick]
set -euo pipefail
cd "$(dirname "$0")/.."
cargo run --release --bin bench-report -- "$@"
echo "wrote $(pwd)/BENCH_1.json"
